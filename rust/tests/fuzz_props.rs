//! Property tests for the fuzz subsystem (README §Fuzzing):
//!
//! * every `FuzzConfig` sample across 100 seeds generates scenarios
//!   that pass validation and survive a bit-identical JSON round-trip;
//! * a minimized repro replays to the exact recorded oracle verdict;
//! * the tournament is thread-count invariant: the serialized
//!   `TournamentReport` and the deterministic telemetry stream are
//!   byte-identical for 1 vs 8 worker threads.

use std::path::Path;
use std::sync::Arc;

use ds3r::app::suite::{self, WifiParams};
use ds3r::app::AppGraph;
use ds3r::fuzz::{
    gen, replay, run_tournament, FuzzConfig, Repro, TournamentOpts,
};
use ds3r::platform::Platform;
use ds3r::scenario::Scenario;
use ds3r::stats::TournamentReport;
use ds3r::telemetry::{self, MemSink, Telemetry};
use ds3r::util::json::Json;

fn apps() -> Vec<AppGraph> {
    vec![suite::wifi_tx(WifiParams { symbols: 2 })]
}

fn small_fuzz(seed: u64) -> FuzzConfig {
    let mut f = FuzzConfig::default();
    f.seed = seed;
    f.cases = 3;
    f.jobs = 15;
    f.min_events = 3;
    f.max_events = 8;
    f.horizon_us = 40_000.0;
    f
}

/// Satellite: 100 fuzz seeds × generated cases — every scenario the
/// generator emits validates (generic and against the Table-2
/// platform/workload) and its JSON form round-trips bit-identically.
#[test]
fn prop_generated_scenarios_validate_and_roundtrip_100_seeds() {
    let p = Platform::table2_soc();
    let n_apps = 2; // exercise the app-mix move too
    for i in 0..100u64 {
        let seed = 0xF00D + i * 7919;
        let mut fc = small_fuzz(seed);
        fc.cases = 4;
        fc.validate().unwrap();
        // FuzzConfig itself round-trips through JSON.
        let back = FuzzConfig::from_json(&fc.to_json()).unwrap();
        assert_eq!(back, fc, "seed {seed}: FuzzConfig JSON round-trip");
        let scenarios = gen::generate_all(&fc, &p, n_apps).unwrap();
        assert_eq!(scenarios.len(), fc.cases);
        for sc in &scenarios {
            sc.validate().unwrap_or_else(|e| {
                panic!("seed {seed} {}: invalid scenario: {e}", sc.name)
            });
            sc.validate_for(&p, n_apps).unwrap_or_else(|e| {
                panic!("seed {seed} {}: platform check: {e}", sc.name)
            });
            let text = sc.to_json().to_string();
            let back =
                Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(&back, sc, "seed {seed}: structural round-trip");
            assert_eq!(
                back.to_json().to_string(),
                text,
                "seed {seed}: byte round-trip"
            );
        }
        // Same seed, fresh generator: bit-identical scenarios.
        let again = gen::generate_all(&fc, &p, n_apps).unwrap();
        assert_eq!(again, scenarios, "seed {seed}: determinism");
    }
}

/// Serializes the tests that run tournaments: they emit through the
/// process-global telemetry dispatcher, and cargo runs tests in
/// parallel threads.
static TEL_GLOBAL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Satellite: an (injected) oracle violation shrinks to a minimized
/// repro whose replay reproduces the recorded verdict bit-identically.
#[test]
fn prop_minimized_repro_replays_bit_identically() {
    let _g = TEL_GLOBAL_LOCK.lock().unwrap();
    let p = Platform::table2_soc();
    let apps = apps();
    let mut fuzz = small_fuzz(99);
    fuzz.cases = 2;
    let dir = std::env::temp_dir().join("ds3r_fuzz_props_repro");
    let _ = std::fs::remove_dir_all(&dir);
    let opts = TournamentOpts {
        schedulers: vec!["etf".into()],
        threads: 2,
        repro_dir: Some(dir.clone()),
        // Every generated scenario opens with a SetRate event, so every
        // cell trips the injected oracle and must shrink + persist.
        inject_label: Some("rate=".into()),
    };
    let (report, _) = run_tournament(&p, &apps, &fuzz, &opts).unwrap();
    assert_eq!(report.violations, 2);
    assert_eq!(report.repros.len(), 2);
    for path in &report.repros {
        let repro = Repro::load(Path::new(path)).unwrap();
        assert!(
            !repro.violations.is_empty(),
            "{path}: repro must record its verdict"
        );
        // JSON round-trip of the repro file itself.
        let text = repro.to_json().to_string();
        let back = Repro::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, repro, "{path}: repro JSON round-trip");
        // Replay lands on the exact recorded verdict.
        let fresh: Vec<(String, String)> = replay(&repro, &p, &apps)
            .unwrap()
            .into_iter()
            .map(|v| (v.oracle, v.detail))
            .collect();
        assert_eq!(fresh, repro.violations, "{path}: replay verdict");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

fn tournament_with_global_memsink(
    threads: usize,
) -> (TournamentReport, String) {
    let p = Platform::table2_soc();
    let apps = apps();
    let fuzz = small_fuzz(4242);
    let opts = TournamentOpts {
        schedulers: vec!["etf".into(), "rr".into(), "met".into()],
        threads,
        repro_dir: None,
        inject_label: None,
    };
    let sink = Arc::new(MemSink::new());
    telemetry::set_global(Telemetry::new(sink.clone()));
    let out = run_tournament(&p, &apps, &fuzz, &opts);
    telemetry::set_global(Telemetry::disabled());
    let (report, _) = out.unwrap();
    (report, sink.dump())
}

/// Satellite: the same fuzz seed at 1 vs 8 worker threads produces a
/// byte-identical serialized `TournamentReport` and a byte-identical
/// telemetry stream.
#[test]
fn prop_tournament_is_thread_count_invariant() {
    let _g = TEL_GLOBAL_LOCK.lock().unwrap();
    let (r1, s1) = tournament_with_global_memsink(1);
    let (r8, s8) = tournament_with_global_memsink(8);
    assert_eq!(r1, r8, "TournamentReport structural identity");
    assert_eq!(
        r1.to_json().to_string_pretty(),
        r8.to_json().to_string_pretty(),
        "TournamentReport byte identity"
    );
    assert_eq!(s1, s8, "telemetry stream byte identity");
    assert_eq!(r1.violations, 0, "{:?}", r1.cells);
    assert!(
        s1.contains("\"event\": \"fuzz_case\""),
        "stream must carry per-cell events: {s1}"
    );
    assert!(
        s1.contains("\"event\": \"tournament_summary\""),
        "stream must close with the summary: {s1}"
    );
}
