//! Figure 3 end-to-end bench: regenerates the paper's scheduling case
//! study (avg job execution time vs injection rate for MET / ETF /
//! ILP-table, WiFi-TX workload on the Table-2 SoC) and reports the
//! simulation cost of every sweep point.
//!
//! Run: `cargo bench --bench fig3_schedulers`

mod bench_util;

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::coordinator;
use ds3r::platform::Platform;
use ds3r::util::plot;

fn main() {
    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams::default())];
    let mut base = SimConfig::default();
    base.max_jobs = 600;
    base.warmup_jobs = 60;
    base.max_sim_us = 5_000_000.0;

    let rates: Vec<f64> = (1..=10).map(|r| r as f64).collect();
    let scheds = ["met", "etf", "ilp"];
    println!("=== Figure 3 regeneration bench ===\n");

    let points = coordinator::fig3_points(&scheds, &rates, base.seed);
    let (results, total_s) = bench_util::bench_once(
        &format!("fig3 sweep ({} points, parallel)", points.len()),
        || {
            coordinator::run_sweep(
                &platform,
                &apps,
                &base,
                &points,
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4),
            )
            .expect("sweep")
        },
    );
    println!(
        "{:>48} {:>12.1} ms/point\n",
        "",
        total_s * 1000.0 / points.len() as f64
    );

    // The paper's figure.
    let series = coordinator::latency_series(&results);
    println!(
        "{}",
        plot::ascii_chart(
            "Figure 3: avg job execution time vs injection rate",
            "jobs/ms",
            "us",
            &series,
            72,
            20
        )
    );
    let mut rows = Vec::new();
    for r in &results {
        rows.push(vec![
            r.point.scheduler.clone(),
            format!("{:.0}", r.point.rate_per_ms),
            format!("{:.1}", r.avg_latency_us),
            format!("{:.3}", r.throughput_jobs_per_ms),
            format!("{:.2}", r.sched_overhead_us),
        ]);
    }
    println!(
        "{}",
        plot::ascii_table(
            &["scheduler", "jobs/ms", "avg us", "thru/ms", "sched us/epoch"],
            &rows
        )
    );
    println!("{}", ds3r::cli::fig3_shape_analysis(&results, &rates));

    // Per-scheduler single-point simulation cost (the framework's own
    // speed — events/sec at a loaded operating point).
    println!("--- simulation kernel cost at 6 jobs/ms ---");
    for s in scheds {
        let mut cfg = base.clone();
        cfg.scheduler = s.into();
        cfg.injection_rate_per_ms = 6.0;
        let (report, secs) = bench_util::bench_once(
            &format!("simulate 600 jobs [{s}]"),
            || {
                ds3r::sim::Simulation::build(&platform, &apps, &cfg)
                    .unwrap()
                    .run()
            },
        );
        println!(
            "{:>48} {:>12.0} events/s\n",
            "",
            report.events_processed as f64 / secs
        );
    }
}
