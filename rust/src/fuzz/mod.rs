//! Seeded scenario fuzzing and the scheduler-robustness tournament.
//!
//! Three layers, used together by the `fuzz` CLI subcommand:
//!
//! - [`gen`] — a deterministic random scenario generator: given a
//!   [`gen::FuzzConfig`] seed, it emits runtime-event timelines (rate
//!   ramps, fault storms with recovery, ambient swings, power-budget
//!   oscillation, app-mix churn, scheduler hot-swaps) that always pass
//!   [`crate::scenario::Scenario::validate`] by construction.
//! - [`oracle`] — reusable invariant oracles over a finished
//!   [`crate::stats::SimReport`]: phase partition, no job loss,
//!   energy == ∫power, finite stats, report/counter consistency.
//! - [`tournament`] — the pooled runner that races every registered
//!   scheduler across the generated scenarios, scores worst-case
//!   robustness, and shrinks any oracle violation to a minimized,
//!   replayable repro JSON.

pub mod gen;
pub mod oracle;
pub mod tournament;

pub use gen::FuzzConfig;
pub use oracle::{check, Violation, ORACLE_NAMES};
pub use tournament::{
    replay, run_tournament, run_tournament_with_policy, Repro,
    TournamentOpts,
};
