//! Golden-trace regression tests: the hot-path overhaul's behavioural
//! contract.
//!
//! For fixed seeds × schedulers on the Table-2 SoC, a run's canonical
//! trace — per-job latencies, per-task (PE, start, finish) Gantt
//! records, energy, event counts — is serialized and compared against a
//! committed golden under `rust/tests/goldens/`.  Any optimization that
//! changes observable behaviour trips these tests.
//!
//! Semantics:
//! * golden file present  → compare (integers exact, floats fp-tolerant
//!   to 1e-6 relative — robust to JSON round-tripping, tight enough
//!   that any real behaviour change, which shifts latencies by whole
//!   microseconds, is caught);
//! * golden file missing  → the trace is written ("blessed") and the
//!   test passes with a notice: commit the generated file.  Generate
//!   goldens from `main` *before* landing a hot-path change;
//! * `GOLDEN_BLESS=1 cargo test --test golden_traces` → re-bless all.

use std::path::PathBuf;

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::Platform;
use ds3r::sim::Simulation;
use ds3r::stats::SimReport;
use ds3r::util::json::Json;

/// The scheduler axis of the golden matrix ("table" is the ILP-backed
/// lookup-table scheduler's registry alias; "il" runs the committed
/// pretrained policy preset, "random" its seeded baseline — both
/// deterministic for a fixed seed, so goldens pin them too).
const SCHEDS: &[&str] =
    &["etf", "met", "heft", "table", "rr", "il", "random"];
const SEEDS: &[u64] = &[42, 1234];

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("goldens")
}

fn golden_cfg(sched: &str, seed: u64) -> SimConfig {
    let mut c = SimConfig::default();
    c.scheduler = sched.into();
    c.seed = seed;
    c.injection_rate_per_ms = 3.0;
    c.max_jobs = 120;
    c.warmup_jobs = 0;
    c.capture_gantt = true;
    c.gantt_limit = 400;
    c
}

fn run_trace(cfg: &SimConfig) -> SimReport {
    let p = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
    Simulation::build(&p, &apps, cfg).unwrap().run()
}

/// Canonical JSON form of a run's observable behaviour.
fn canonical(cfg: &SimConfig, r: &SimReport) -> Json {
    let mut j = Json::obj();
    j.set("scheduler", Json::Str(cfg.scheduler.clone()))
        .set("seed", Json::Num(cfg.seed as f64))
        .set("injected_jobs", Json::Num(r.injected_jobs as f64))
        .set("completed_jobs", Json::Num(r.completed_jobs as f64))
        .set("events_processed", Json::Num(r.events_processed as f64))
        .set("tasks_executed", Json::Num(r.tasks_executed as f64))
        .set("total_energy_j", Json::Num(r.total_energy_j))
        .set("peak_temp_c", Json::Num(r.peak_temp_c))
        .set(
            "job_latencies_us",
            Json::Arr(
                r.job_latencies_us.iter().map(|&l| Json::Num(l)).collect(),
            ),
        )
        .set(
            "gantt",
            Json::Arr(
                r.gantt
                    .iter()
                    .map(|e| {
                        Json::Arr(vec![
                            Json::Num(e.job as f64),
                            Json::Num(e.task as f64),
                            Json::Num(e.pe as f64),
                            Json::Num(e.start_us),
                            Json::Num(e.end_us),
                        ])
                    })
                    .collect(),
            ),
        );
    j
}

fn f64_of(j: &Json, key: &str, ctx: &str) -> f64 {
    j.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("{ctx}: golden missing '{key}'"))
}

fn assert_close(ctx: &str, what: &str, got: f64, want: f64) {
    let tol = 1e-6 * want.abs().max(1e-6);
    assert!(
        (got - want).abs() <= tol,
        "{ctx}: {what} diverged from golden: got {got}, want {want}"
    );
}

fn compare(ctx: &str, got: &Json, want: &Json) {
    for key in [
        "injected_jobs",
        "completed_jobs",
        "events_processed",
        "tasks_executed",
    ] {
        assert_eq!(
            f64_of(got, key, ctx) as u64,
            f64_of(want, key, ctx) as u64,
            "{ctx}: {key} diverged from golden"
        );
    }
    assert_close(
        ctx,
        "total_energy_j",
        f64_of(got, "total_energy_j", ctx),
        f64_of(want, "total_energy_j", ctx),
    );
    assert_close(
        ctx,
        "peak_temp_c",
        f64_of(got, "peak_temp_c", ctx),
        f64_of(want, "peak_temp_c", ctx),
    );

    let lat_g = got.get("job_latencies_us").and_then(Json::as_arr).unwrap();
    let lat_w =
        want.get("job_latencies_us").and_then(Json::as_arr).unwrap();
    assert_eq!(lat_g.len(), lat_w.len(), "{ctx}: latency count");
    for (i, (a, b)) in lat_g.iter().zip(lat_w).enumerate() {
        assert_close(
            ctx,
            &format!("latency[{i}]"),
            a.as_f64().unwrap(),
            b.as_f64().unwrap(),
        );
    }

    let g_g = got.get("gantt").and_then(Json::as_arr).unwrap();
    let g_w = want.get("gantt").and_then(Json::as_arr).unwrap();
    assert_eq!(g_g.len(), g_w.len(), "{ctx}: gantt length");
    for (i, (a, b)) in g_g.iter().zip(g_w).enumerate() {
        let a = a.as_arr().unwrap();
        let b = b.as_arr().unwrap();
        for f in 0..3 {
            // job, task, pe: exact.
            assert_eq!(
                a[f].as_f64().unwrap() as u64,
                b[f].as_f64().unwrap() as u64,
                "{ctx}: gantt[{i}] field {f} (job/task/pe) diverged"
            );
        }
        for f in 3..5 {
            assert_close(
                ctx,
                &format!("gantt[{i}] field {f}"),
                a[f].as_f64().unwrap(),
                b[f].as_f64().unwrap(),
            );
        }
    }
}

#[test]
fn golden_traces_all_schedulers() {
    let bless_all = std::env::var("GOLDEN_BLESS")
        .map(|v| v == "1")
        .unwrap_or(false);
    let dir = golden_dir();
    for &sched in SCHEDS {
        for &seed in SEEDS {
            let cfg = golden_cfg(sched, seed);
            let r = run_trace(&cfg);
            assert_eq!(
                r.completed_jobs, r.injected_jobs,
                "{sched}/s{seed}: jobs lost"
            );
            let got = canonical(&cfg, &r);
            let path = dir.join(format!("{sched}_s{seed}.json"));
            if bless_all || !path.exists() {
                std::fs::create_dir_all(&dir).unwrap();
                std::fs::write(&path, got.to_string_pretty()).unwrap();
                eprintln!(
                    "golden blessed: {} — commit it to pin this \
                     behaviour",
                    path.display()
                );
                continue;
            }
            let want = Json::parse_file(&path).unwrap_or_else(|e| {
                panic!("{sched}/s{seed}: unreadable golden: {e}")
            });
            compare(&format!("{sched}/s{seed}"), &got, &want);
        }
    }
}

/// The run used for goldens must itself be deterministic, otherwise the
/// bless-compare cycle would flap.
#[test]
fn golden_configs_are_deterministic() {
    let cfg = golden_cfg("etf", 42);
    let a = run_trace(&cfg);
    let b = run_trace(&cfg);
    assert_eq!(a.job_latencies_us, b.job_latencies_us);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
}

/// Cross-path golden: the lazy integration lane against the eager
/// reference path, bit-exact, for every golden config.  This guard
/// holds even before the on-disk goldens are first blessed.
#[test]
fn golden_lazy_vs_eager_bit_identical() {
    for &sched in SCHEDS {
        let lazy_cfg = golden_cfg(sched, 42);
        let mut eager_cfg = lazy_cfg.clone();
        eager_cfg.eager_integration = true;
        let a = run_trace(&lazy_cfg);
        let b = run_trace(&eager_cfg);
        assert_eq!(a.job_latencies_us, b.job_latencies_us, "{sched}");
        assert_eq!(a.events_processed, b.events_processed, "{sched}");
        assert_eq!(a.tasks_executed, b.tasks_executed, "{sched}");
        assert_eq!(
            a.total_energy_j.to_bits(),
            b.total_energy_j.to_bits(),
            "{sched}: energy diverged between lazy and eager integration"
        );
        assert_eq!(
            a.peak_temp_c.to_bits(),
            b.peak_temp_c.to_bits(),
            "{sched}: peak temperature diverged"
        );
        assert_eq!(a.gantt.len(), b.gantt.len(), "{sched}");
        for (x, y) in a.gantt.iter().zip(&b.gantt) {
            assert_eq!(
                (x.job, x.task, x.pe),
                (y.job, y.task, y.pe),
                "{sched}: gantt assignment diverged"
            );
            assert_eq!(x.start_us.to_bits(), y.start_us.to_bits());
            assert_eq!(x.end_us.to_bits(), y.end_us.to_bits());
        }
    }
}
