//! Platform (de)serialization: define custom DSSoCs in JSON.
//!
//! Together with the JSON application format (`AppGraph::from_json`)
//! this makes the whole design space file-driven: a platform file, an
//! application file, and a `SimConfig` fully describe an experiment.
//!
//! ```json
//! {
//!   "name": "my-dssoc",
//!   "t_ambient": 25.0,
//!   "mesh": {"x": 4, "y": 4, "hop_latency_us": 0.05,
//!            "link_bandwidth": 8000, "mem_latency_us": 0.5},
//!   "classes": [
//!     {"name": "A15", "type": "big", "nominal_mhz": 2000,
//!      "ceff": 5.5e-4, "leak_k1": 7.5e-3, "leak_k2": 0.025,
//!      "opps": [[200, 0.9], [2000, 1.31]]}
//!   ],
//!   "clusters": [
//!     {"name": "A15", "class": "A15", "thermal_node": 0,
//!      "pes": [[0, 3], [1, 3]]}
//!   ],
//!   "floorplan": {
//!     "nodes": [{"name": "big", "capacitance": 0.35, "g_amb": 0.12}],
//!     "couplings": [[0, 1, 0.3]]
//!   }
//! }
//! ```

use super::{
    Cluster, NocParams, Opp, Pe, PeClass, PeType, Platform,
    ThermalFloorplan,
};
use crate::util::json::Json;
use crate::{Error, Result};

impl PeType {
    fn parse(s: &str) -> Result<PeType> {
        match s {
            "big" => Ok(PeType::BigCore),
            "LITTLE" | "little" => Ok(PeType::LittleCore),
            "accelerator" => Ok(PeType::Accelerator),
            other => Err(Error::Platform(format!(
                "unknown PE type '{other}' (big, LITTLE, accelerator)"
            ))),
        }
    }
}

impl Platform {
    /// Parse a platform description (see module docs for the schema).
    pub fn from_json(j: &Json) -> Result<Platform> {
        let name = j.req_str("name")?.to_string();

        // --- NoC ---
        let noc = match j.get("mesh") {
            None => NocParams::default(),
            Some(m) => NocParams {
                mesh_x: m.req_f64("x")? as usize,
                mesh_y: m.req_f64("y")? as usize,
                hop_latency_us: m
                    .get("hop_latency_us")
                    .and_then(Json::as_f64)
                    .unwrap_or(NocParams::default().hop_latency_us),
                link_bandwidth: m
                    .get("link_bandwidth")
                    .and_then(Json::as_f64)
                    .unwrap_or(NocParams::default().link_bandwidth),
                mem_latency_us: m
                    .get("mem_latency_us")
                    .and_then(Json::as_f64)
                    .unwrap_or(NocParams::default().mem_latency_us),
            },
        };

        // --- classes ---
        let mut classes = Vec::new();
        for jc in j.req_arr("classes")? {
            let opps = jc
                .req_arr("opps")?
                .iter()
                .map(|o| {
                    let pair = o.f64_vec()?;
                    if pair.len() != 2 {
                        return Err(Error::Platform(
                            "opp must be [freq_mhz, volt]".into(),
                        ));
                    }
                    Ok(Opp { freq_mhz: pair[0], volt: pair[1] })
                })
                .collect::<Result<Vec<_>>>()?;
            classes.push(PeClass {
                name: jc.req_str("name")?.to_string(),
                ty: PeType::parse(jc.req_str("type")?)?,
                nominal_mhz: jc.req_f64("nominal_mhz")?,
                opps,
                ceff: jc.req_f64("ceff")?,
                leak_k1: jc.req_f64("leak_k1")?,
                leak_k2: jc.req_f64("leak_k2")?,
            });
        }
        let class_idx = |n: &str| {
            classes
                .iter()
                .position(|c| c.name == n)
                .ok_or_else(|| {
                    Error::Platform(format!("unknown class '{n}'"))
                })
        };

        // --- floorplan ---
        let fp = j
            .get("floorplan")
            .ok_or_else(|| Error::Platform("missing floorplan".into()))?;
        let mut node_names = Vec::new();
        let mut capacitance = Vec::new();
        let mut g_amb = Vec::new();
        for n in fp.req_arr("nodes")? {
            node_names.push(n.req_str("name")?.to_string());
            capacitance.push(n.req_f64("capacitance")?);
            g_amb.push(n.req_f64("g_amb")?);
        }
        let couplings = fp
            .req_arr("couplings")?
            .iter()
            .map(|c| {
                let t = c.f64_vec()?;
                if t.len() != 3 {
                    return Err(Error::Platform(
                        "coupling must be [i, j, conductance]".into(),
                    ));
                }
                Ok((t[0] as usize, t[1] as usize, t[2]))
            })
            .collect::<Result<Vec<_>>>()?;
        let floorplan = ThermalFloorplan {
            node_names,
            capacitance,
            g_amb,
            couplings,
        };

        // --- clusters + PEs ---
        let mut pes: Vec<Pe> = Vec::new();
        let mut clusters = Vec::new();
        for (cid, jc) in j.req_arr("clusters")?.iter().enumerate() {
            let cname = jc.req_str("name")?.to_string();
            let class = class_idx(jc.req_str("class")?)?;
            let thermal_node = jc.req_f64("thermal_node")? as usize;
            let mut pe_ids = Vec::new();
            for (i, jp) in jc.req_arr("pes")?.iter().enumerate() {
                let xy = jp.f64_vec()?;
                if xy.len() != 2 {
                    return Err(Error::Platform(
                        "pe must be [x, y]".into(),
                    ));
                }
                let id = pes.len();
                pes.push(Pe {
                    id,
                    class,
                    cluster: cid,
                    name: format!("{cname}-{i}"),
                    x: xy[0] as usize,
                    y: xy[1] as usize,
                });
                pe_ids.push(id);
            }
            clusters.push(Cluster {
                id: cid,
                name: cname,
                class,
                pe_ids,
                thermal_node,
            });
        }

        let mut platform =
            Platform::new(name, classes, pes, clusters, noc, floorplan)?;
        // Optional: ambient temperature (°C).  Without the key the
        // constructor default (25 °C) stands — older platform files
        // keep loading unchanged.
        if let Some(t) = j.get("t_ambient").and_then(Json::as_f64) {
            platform.t_ambient = t;
        }
        Ok(platform)
    }

    pub fn from_json_file(path: &std::path::Path) -> Result<Platform> {
        Platform::from_json(&Json::parse_file(path)?)
    }

    /// Serialize (inverse of [`Platform::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("t_ambient", Json::Num(self.t_ambient));

        let mut mesh = Json::obj();
        mesh.set("x", Json::Num(self.noc.mesh_x as f64))
            .set("y", Json::Num(self.noc.mesh_y as f64))
            .set("hop_latency_us", Json::Num(self.noc.hop_latency_us))
            .set("link_bandwidth", Json::Num(self.noc.link_bandwidth))
            .set("mem_latency_us", Json::Num(self.noc.mem_latency_us));
        j.set("mesh", mesh);

        let classes = self
            .classes
            .iter()
            .map(|c| {
                let mut jc = Json::obj();
                jc.set("name", Json::Str(c.name.clone()))
                    .set("type", Json::Str(c.ty.label().into()))
                    .set("nominal_mhz", Json::Num(c.nominal_mhz))
                    .set("ceff", Json::Num(c.ceff))
                    .set("leak_k1", Json::Num(c.leak_k1))
                    .set("leak_k2", Json::Num(c.leak_k2))
                    .set(
                        "opps",
                        Json::Arr(
                            c.opps
                                .iter()
                                .map(|o| {
                                    Json::Arr(vec![
                                        Json::Num(o.freq_mhz),
                                        Json::Num(o.volt),
                                    ])
                                })
                                .collect(),
                        ),
                    );
                jc
            })
            .collect();
        j.set("classes", Json::Arr(classes));

        let clusters = self
            .clusters
            .iter()
            .map(|cl| {
                let mut jc = Json::obj();
                jc.set("name", Json::Str(cl.name.clone()))
                    .set(
                        "class",
                        Json::Str(self.classes[cl.class].name.clone()),
                    )
                    .set("thermal_node", Json::Num(cl.thermal_node as f64))
                    .set(
                        "pes",
                        Json::Arr(
                            cl.pe_ids
                                .iter()
                                .map(|&p| {
                                    Json::Arr(vec![
                                        Json::Num(self.pes[p].x as f64),
                                        Json::Num(self.pes[p].y as f64),
                                    ])
                                })
                                .collect(),
                        ),
                    );
                jc
            })
            .collect();
        j.set("clusters", Json::Arr(clusters));

        let mut fp = Json::obj();
        let nodes = (0..self.floorplan.len())
            .map(|i| {
                let mut n = Json::obj();
                n.set(
                    "name",
                    Json::Str(self.floorplan.node_names[i].clone()),
                )
                .set("capacitance", Json::Num(self.floorplan.capacitance[i]))
                .set("g_amb", Json::Num(self.floorplan.g_amb[i]));
                n
            })
            .collect();
        fp.set("nodes", Json::Arr(nodes));
        fp.set(
            "couplings",
            Json::Arr(
                self.floorplan
                    .couplings
                    .iter()
                    .map(|&(a, b, g)| {
                        Json::Arr(vec![
                            Json::Num(a as f64),
                            Json::Num(b as f64),
                            Json::Num(g),
                        ])
                    })
                    .collect(),
            ),
        );
        j.set("floorplan", fp);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_roundtrips_through_json() {
        let p = Platform::table2_soc();
        let j = p.to_json();
        let p2 = Platform::from_json(&j).unwrap();
        assert_eq!(p2.name, p.name);
        assert_eq!(p2.n_pes(), p.n_pes());
        assert_eq!(p2.classes.len(), p.classes.len());
        for (a, b) in p.classes.iter().zip(&p2.classes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.ty, b.ty);
            assert_eq!(a.opps, b.opps);
            assert_eq!(a.ceff, b.ceff);
        }
        for (a, b) in p.pes.iter().zip(&p2.pes) {
            assert_eq!((a.x, a.y, a.class, a.cluster), (b.x, b.y, b.class, b.cluster));
        }
        assert_eq!(p2.floorplan.couplings, p.floorplan.couplings);
        // Round-tripped platform simulates identically.
        use crate::app::suite::{self, WifiParams};
        use crate::config::SimConfig;
        use crate::sim::Simulation;
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 3 })];
        let mut cfg = SimConfig::default();
        cfg.max_jobs = 30;
        cfg.warmup_jobs = 3;
        let r1 = Simulation::build(&p, &apps, &cfg).unwrap().run();
        let r2 = Simulation::build(&p2, &apps, &cfg).unwrap().run();
        assert_eq!(r1.job_latencies_us, r2.job_latencies_us);
    }

    /// Field-by-field equality of a platform and its JSON round-trip —
    /// any field the (de)serializer silently drops (and hence any field
    /// the DSE genome decode path would lose when re-materializing a
    /// design from a checkpointed platform) fails here by name.
    fn assert_roundtrip_exact(p: &Platform) {
        let p2 = Platform::from_json(&p.to_json()).unwrap();
        assert_eq!(p2.name, p.name, "name");
        assert_eq!(p2.t_ambient, p.t_ambient, "t_ambient");
        assert_eq!(p2.noc.mesh_x, p.noc.mesh_x, "mesh_x");
        assert_eq!(p2.noc.mesh_y, p.noc.mesh_y, "mesh_y");
        assert_eq!(
            p2.noc.hop_latency_us, p.noc.hop_latency_us,
            "hop_latency_us"
        );
        assert_eq!(
            p2.noc.link_bandwidth, p.noc.link_bandwidth,
            "link_bandwidth"
        );
        assert_eq!(
            p2.noc.mem_latency_us, p.noc.mem_latency_us,
            "mem_latency_us"
        );
        assert_eq!(p2.classes.len(), p.classes.len(), "class count");
        for (a, b) in p.classes.iter().zip(&p2.classes) {
            assert_eq!(a.name, b.name, "class name");
            assert_eq!(a.ty, b.ty, "class type of {}", a.name);
            assert_eq!(
                a.nominal_mhz, b.nominal_mhz,
                "nominal_mhz of {}",
                a.name
            );
            assert_eq!(a.opps, b.opps, "opps of {}", a.name);
            assert_eq!(a.ceff, b.ceff, "ceff of {}", a.name);
            assert_eq!(a.leak_k1, b.leak_k1, "leak_k1 of {}", a.name);
            assert_eq!(a.leak_k2, b.leak_k2, "leak_k2 of {}", a.name);
        }
        assert_eq!(p2.n_pes(), p.n_pes(), "pe count");
        for (a, b) in p.pes.iter().zip(&p2.pes) {
            assert_eq!(a.id, b.id, "pe id");
            assert_eq!(a.class, b.class, "class of pe {}", a.id);
            assert_eq!(a.cluster, b.cluster, "cluster of pe {}", a.id);
            assert_eq!((a.x, a.y), (b.x, b.y), "coords of pe {}", a.id);
        }
        assert_eq!(p2.clusters.len(), p.clusters.len(), "cluster count");
        for (a, b) in p.clusters.iter().zip(&p2.clusters) {
            assert_eq!(a.id, b.id, "cluster id");
            assert_eq!(a.name, b.name, "cluster name");
            assert_eq!(a.class, b.class, "class of cluster {}", a.name);
            assert_eq!(a.pe_ids, b.pe_ids, "pe_ids of cluster {}", a.name);
            assert_eq!(
                a.thermal_node, b.thermal_node,
                "thermal_node of cluster {}",
                a.name
            );
        }
        assert_eq!(
            p2.floorplan.node_names, p.floorplan.node_names,
            "floorplan node names"
        );
        assert_eq!(
            p2.floorplan.capacitance, p.floorplan.capacitance,
            "floorplan capacitance"
        );
        assert_eq!(p2.floorplan.g_amb, p.floorplan.g_amb, "floorplan g_amb");
        assert_eq!(
            p2.floorplan.couplings, p.floorplan.couplings,
            "floorplan couplings"
        );
    }

    #[test]
    fn table2_preset_roundtrips_every_field() {
        assert_roundtrip_exact(&Platform::table2_soc());
    }

    #[test]
    fn zcu102_preset_roundtrips_every_field() {
        assert_roundtrip_exact(&crate::platform::presets::zcu102_soc());
    }

    #[test]
    fn t_ambient_roundtrips_and_defaults() {
        let mut p = Platform::table2_soc();
        p.t_ambient = 41.5;
        let p2 = Platform::from_json(&p.to_json()).unwrap();
        assert_eq!(p2.t_ambient, 41.5);
        // Files without the key keep the constructor default.
        let mut j = p.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("t_ambient");
        }
        let p3 = Platform::from_json(&j).unwrap();
        assert_eq!(p3.t_ambient, 25.0);
    }

    #[test]
    fn rejects_unknown_class_reference() {
        let p = Platform::table2_soc();
        let mut j = p.to_json();
        // Point a cluster at a class that does not exist.
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(cl)) = m.get_mut("clusters") {
                cl[0].set("class", Json::Str("WARP_CORE".into()));
            }
        }
        assert!(Platform::from_json(&j).is_err());
    }

    #[test]
    fn rejects_malformed_opp() {
        let text = r#"{
          "name": "x",
          "classes": [{"name": "c", "type": "big", "nominal_mhz": 1000,
                       "ceff": 1e-4, "leak_k1": 0.001, "leak_k2": 0.01,
                       "opps": [[1000]]}],
          "clusters": [], "floorplan": {"nodes": [], "couplings": []}
        }"#;
        let j = Json::parse(text).unwrap();
        assert!(Platform::from_json(&j).is_err());
    }

    #[test]
    fn pe_type_parse() {
        assert_eq!(PeType::parse("big").unwrap(), PeType::BigCore);
        assert_eq!(PeType::parse("LITTLE").unwrap(), PeType::LittleCore);
        assert_eq!(
            PeType::parse("accelerator").unwrap(),
            PeType::Accelerator
        );
        assert!(PeType::parse("quantum").is_err());
    }
}
