"""Golden-vector self-consistency: the JSON files the rust integration
tests consume must round-trip through JSON and agree with the oracle."""

import hashlib
import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot
from compile.kernels import ref
from compile.kernels.etf import I, J
from compile.kernels.thermal import K, N, P


@pytest.fixture(scope="module")
def golden_dir():
    with tempfile.TemporaryDirectory() as d:
        aot.write_goldens(d)
        yield d


def test_dtpm_golden_matches_oracle(golden_dir):
    with open(os.path.join(golden_dir, "golden_dtpm.json")) as f:
        g = json.load(f)
    ins = {k: np.asarray(v, np.float32) for k, v in g["inputs"].items()}
    t = ins["t"].reshape(K, N)
    a = ins["a"].reshape(N, N)
    b = ins["b"].reshape(N, P)
    pd = ins["pd"].reshape(K, P)
    v = ins["v"].reshape(K, P)
    k1 = ins["k1"].reshape(1, P)
    k2 = ins["k2"].reshape(1, P)
    pe_node = ins["pe_node"].reshape(P, N)
    t_next, p_leak, p_tot = ref.dtpm_step_ref(t, a, b, pd, v, k1, k2,
                                              pe_node)
    t_next = np.clip(np.asarray(t_next), 0.0, 105.0)
    np.testing.assert_allclose(
        np.asarray(g["outputs"]["t_next"]).reshape(K, N), t_next,
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(g["outputs"]["p_leak"]).reshape(K, P),
        np.asarray(p_leak), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(g["outputs"]["p_sum"]).reshape(K, 1),
        np.asarray(p_tot).sum(axis=1, keepdims=True), rtol=1e-5)


def test_etf_golden_matches_oracle(golden_dir):
    with open(os.path.join(golden_dir, "golden_etf.json")) as f:
        g = json.load(f)
    avail = np.asarray(g["inputs"]["avail"], np.float32).reshape(1, J)
    ready = np.asarray(g["inputs"]["ready"], np.float32).reshape(I, J)
    exe = np.asarray(g["inputs"]["exec"], np.float32).reshape(I, J)
    fin, best_pe, best_fin = ref.etf_matrix_ref(avail, ready, exe)
    np.testing.assert_allclose(
        np.asarray(g["outputs"]["finish"]).reshape(I, J),
        np.asarray(fin), rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(g["outputs"]["best_pe"]).reshape(I, 1),
        np.asarray(best_pe))


def test_goldens_deterministic(golden_dir):
    """write_goldens must be reproducible (fixed seed 42)."""
    with tempfile.TemporaryDirectory() as d2:
        aot.write_goldens(d2)
        for name in ["golden_dtpm.json", "golden_etf.json"]:
            h1 = hashlib.sha256(
                open(os.path.join(golden_dir, name), "rb").read()
            ).hexdigest()
            h2 = hashlib.sha256(
                open(os.path.join(d2, name), "rb").read()
            ).hexdigest()
            assert h1 == h2, f"{name} not deterministic"


def test_manifest_digests_match_artifacts():
    """If artifacts/ exists, its manifest must describe its files."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("run `make artifacts` first")
    with open(manifest_path) as f:
        manifest = json.load(f)
    for name, meta in manifest.items():
        path = os.path.join(art, name)
        assert os.path.exists(path), f"{name} missing"
        digest = hashlib.sha256(open(path, "rb").read()).hexdigest()
        assert digest == meta["sha256"], (
            f"{name} stale: rerun `make artifacts`")
        assert meta["bytes"] == os.path.getsize(path)
