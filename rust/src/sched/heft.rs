//! HEFT-style list scheduler (extension baseline).
//!
//! Heterogeneous Earliest Finish Time (Topcuoglu et al.): tasks are
//! prioritized by *upward rank* — mean execution time plus the maximum
//! (mean communication + rank) over successors — and each is placed on
//! the PE minimizing its earliest finish time.  HEFT is not in the WiP
//! paper's built-in list; it exercises the plug-and-play interface and
//! serves as a stronger static-priority baseline in the ablation benches.

use std::collections::BTreeMap;

use super::{Assignment, ReadyTask, SchedBuild, SchedContext, Scheduler};

#[derive(Debug)]
pub struct Heft {
    /// `ranks[app][task]` — upward rank (µs).
    ranks: Vec<Vec<f64>>,
    epochs: u64,
}

impl Heft {
    pub fn new(build: &SchedBuild) -> Heft {
        // Mean comm cost approximation: bytes / bandwidth + mean-hops ×
        // hop latency (contention-free, platform-wide average distance).
        let noc = &build.platform.noc;
        let mean_hops = (noc.mesh_x + noc.mesh_y) as f64 / 2.0;
        let comm_us = |bytes: u64| {
            if bytes == 0 {
                0.0
            } else {
                bytes as f64 / noc.link_bandwidth
                    + mean_hops * noc.hop_latency_us
                    + noc.mem_latency_us
            }
        };
        let mut ranks = Vec::with_capacity(build.apps.len());
        for app in build.apps {
            let mut r = vec![0.0f64; app.len()];
            for &t in app.topo_order().iter().rev() {
                let w = app.tasks[t].mean_exec_us();
                let down = app
                    .succs(t)
                    .iter()
                    .map(|&s| comm_us(app.tasks[t].out_bytes) + r[s])
                    .fold(0.0, f64::max);
                r[t] = w + down;
            }
            ranks.push(r);
        }
        Heft { ranks, epochs: 0 }
    }

    fn rank(&self, rt: &ReadyTask) -> f64 {
        self.ranks
            .get(rt.app)
            .and_then(|r| r.get(rt.task))
            .copied()
            .unwrap_or(0.0)
    }
}

impl Scheduler for Heft {
    fn name(&self) -> &str {
        "heft"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        self.epochs += 1;
        // Order by descending upward rank (critical tasks first).
        let mut order: Vec<usize> = (0..ready.len()).collect();
        order.sort_by(|&a, &b| {
            self.rank(&ready[b])
                .partial_cmp(&self.rank(&ready[a]))
                .unwrap()
                .then(ready[a].job.cmp(&ready[b].job))
        });

        let now = ctx.now_us();
        let mut avail: Vec<f64> =
            ctx.pes().iter().map(|p| p.avail_us).collect();
        let mut out = Vec::with_capacity(ready.len());
        for idx in order {
            let rt = &ready[idx];
            let mut best = (f64::INFINITY, usize::MAX);
            for pe in ctx.pes() {
                if !pe.available {
                    continue; // failed/hotplugged-out (scenario engine)
                }
                if let Some(e) = ctx.exec_us(rt, pe.id) {
                    let start = avail[pe.id]
                        .max(ctx.data_ready_us(rt, pe.id))
                        .max(now);
                    let fin = start + e;
                    if fin < best.0 {
                        best = (fin, pe.id);
                    }
                }
            }
            if best.1 == usize::MAX {
                continue;
            }
            avail[best.1] = best.0;
            out.push(Assignment { job: rt.job, task: rt.task, pe: best.1 });
        }
        out
    }

    fn report(&self) -> Vec<String> {
        vec![format!("heft: {} epochs", self.epochs)]
    }
}

/// Expose ranks for tests/diagnostics.
impl Heft {
    pub fn ranks_for(&self, app: usize) -> &[f64] {
        &self.ranks[app]
    }

    pub fn ranks_by_name(
        &self,
        app: usize,
        graph: &crate::app::AppGraph,
    ) -> BTreeMap<String, f64> {
        graph
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), self.ranks[app][i]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};
    use crate::platform::Platform;
    use crate::sched::testutil::{rt, MockCtx};

    fn make() -> (Platform, Vec<crate::app::AppGraph>) {
        (
            Platform::table2_soc(),
            vec![suite::wifi_tx(WifiParams { symbols: 2 })],
        )
    }

    #[test]
    fn source_has_highest_rank() {
        let (platform, apps) = make();
        let h = Heft::new(&SchedBuild {
            platform: &platform,
            apps: &apps,
            seed: 0,
            artifacts_dir: None,
            policy_path: None,
        });
        let ranks = h.ranks_for(0);
        // Source (scrambler) dominates: its rank includes the whole DAG.
        let max = ranks.iter().copied().fold(0.0, f64::max);
        assert_eq!(ranks[0], max);
        // Sink (crc) has the smallest rank.
        let crc = apps[0].len() - 1;
        let min = ranks.iter().copied().fold(f64::INFINITY, f64::min);
        assert_eq!(ranks[crc], min);
    }

    #[test]
    fn ranks_decrease_along_edges() {
        let (platform, apps) = make();
        let h = Heft::new(&SchedBuild {
            platform: &platform,
            apps: &apps,
            seed: 0,
            artifacts_dir: None,
            policy_path: None,
        });
        let g = &apps[0];
        for (i, t) in g.tasks.iter().enumerate() {
            for &p in &t.preds {
                assert!(
                    h.ranks_for(0)[p] > h.ranks_for(0)[i],
                    "rank({p}) <= rank({i})"
                );
            }
        }
    }

    #[test]
    fn prioritizes_high_rank_tasks_under_contention() {
        let (platform, apps) = make();
        let mut h = Heft::new(&SchedBuild {
            platform: &platform,
            apps: &apps,
            seed: 0,
            artifacts_dir: None,
            policy_path: None,
        });
        // One PE, two tasks: task 0 (source, high rank) vs the crc sink
        // (low rank). HEFT must commit the high-rank task first.
        let mut ctx = MockCtx::uniform(1, 0.0);
        let crc = apps[0].len() - 1;
        ctx.set_exec(0, 0, 0, 10.0);
        ctx.set_exec(0, crc, 0, 10.0);
        let a = h.schedule(&[rt(0, crc), rt(0, 0)], &ctx);
        assert_eq!(a[0].task, 0);
        assert_eq!(a[1].task, crc);
    }

    #[test]
    fn assigns_min_eft_pe() {
        let (platform, apps) = make();
        let mut h = Heft::new(&SchedBuild {
            platform: &platform,
            apps: &apps,
            seed: 0,
            artifacts_dir: None,
            policy_path: None,
        });
        let mut ctx = MockCtx::uniform(2, 0.0);
        ctx.set_exec(0, 0, 0, 100.0);
        ctx.set_exec(0, 0, 1, 20.0);
        let a = h.schedule(&[rt(0, 0)], &ctx);
        assert_eq!(a[0].pe, 1);
    }
}
