//! Figure 2 bench: regenerates the WiFi-TX application DAG and measures
//! graph-model operations over the whole benchmark suite (construction,
//! topological sort, critical-path analysis, JSON round-trip).
//!
//! Run: `cargo bench --bench fig2_dag`

mod bench_util;

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::app::AppGraph;

fn main() {
    println!("=== Figure 2 regeneration ===\n");
    println!("{}", ds3r::cli::reproduce_fig2());

    println!("--- DAG-model microbenchmarks ---");
    bench_util::bench("wifi_tx build+validate (50 tasks)", 20_000, || {
        std::hint::black_box(suite::wifi_tx(WifiParams::default()));
    });
    bench_util::bench("wifi_rx build+validate (large)", 5_000, || {
        std::hint::black_box(suite::wifi_rx(WifiParams::default()));
    });
    bench_util::bench("pulse_doppler build+validate", 10_000, || {
        std::hint::black_box(suite::pulse_doppler(RadarParams::default()));
    });

    let g = suite::wifi_tx(WifiParams::default());
    bench_util::bench("critical_path_us (50 tasks)", 200_000, || {
        std::hint::black_box(g.critical_path_us());
    });
    bench_util::bench("max_width (50 tasks)", 200_000, || {
        std::hint::black_box(g.max_width());
    });
    let j = g.to_json();
    bench_util::bench("DAG JSON serialize", 20_000, || {
        std::hint::black_box(g.to_json());
    });
    bench_util::bench("DAG JSON parse+validate", 10_000, || {
        std::hint::black_box(AppGraph::from_json(&j).unwrap());
    });

    println!("\n--- suite inventory (all five reference applications) ---");
    for app in suite::all_default() {
        println!(
            "  {:<16} {:>4} tasks  width {:>3}  critical path {:>8.1} us  total work {:>9.1} us",
            app.name,
            app.len(),
            app.max_width(),
            app.critical_path_us(),
            app.total_work_us()
        );
    }
}
