//! Demonstration datasets and the DAgger collector.
//!
//! A [`Sample`] is one scheduling decision: the feature vectors of every
//! candidate PE plus the index of the oracle's choice.  [`Collector`]
//! is a [`Scheduler`] wrapper that records these while a simulation
//! runs: in round 0 the oracle both *acts* and *labels* (behavioural
//! cloning); in later rounds the current policy acts while the oracle
//! keeps labelling — DAgger-style aggregation, so the dataset covers the
//! states the deployed policy actually visits, not just the oracle's
//! trajectory.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::sched::{Assignment, ReadyTask, SchedContext, Scheduler};
use crate::util::json::Json;
use crate::{Error, Result};

use super::features::{candidates, features_into, FeatureCtx, N_FEATURES};
use super::model::SoftmaxModel;
use super::policy::choose_guarded;

/// One recorded decision: candidate PE classes + features, oracle label.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Index of the oracle's choice within the candidate list.
    pub chosen: u32,
    /// PE class per candidate.
    pub classes: Vec<u16>,
    /// `classes.len() × N_FEATURES` row-major feature matrix.
    pub feats: Vec<f64>,
}

/// An aggregated demonstration set (JSON-serializable so collection and
/// training can run as separate CLI steps).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Dataset {
    pub samples: Vec<Sample>,
    /// Name of the oracle that produced the labels (stamped by
    /// `collect_round`; empty for hand-built sets).  `learn train
    /// --data` prefers this over its own default so the policy artifact
    /// records the oracle it actually imitates.
    pub oracle: String,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// DAgger aggregation: append another round's demonstrations.
    pub fn extend(&mut self, other: Dataset) {
        self.samples.extend(other.samples);
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str("ds3r-il-dataset".into()))
            .set("n_features", Json::Num(N_FEATURES as f64));
        if !self.oracle.is_empty() {
            j.set("oracle", Json::Str(self.oracle.clone()));
        }
        j.set(
                "samples",
                Json::Arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            let mut js = Json::obj();
                            js.set("chosen", Json::Num(s.chosen as f64))
                                .set(
                                    "classes",
                                    Json::Arr(
                                        s.classes
                                            .iter()
                                            .map(|&c| Json::Num(c as f64))
                                            .collect(),
                                    ),
                                )
                                .set(
                                    "feats",
                                    Json::Arr(
                                        s.feats
                                            .iter()
                                            .map(|&x| Json::Num(x))
                                            .collect(),
                                    ),
                                );
                            js
                        })
                        .collect(),
                ),
            );
        j
    }

    pub fn from_json(j: &Json) -> Result<Dataset> {
        if let Some(kind) = j.get("kind").and_then(Json::as_str) {
            if kind != "ds3r-il-dataset" {
                return Err(Error::Config(format!(
                    "not an IL dataset (kind '{kind}')"
                )));
            }
        }
        let nf = j
            .get("n_features")
            .and_then(Json::as_usize)
            .unwrap_or(N_FEATURES);
        if nf != N_FEATURES {
            return Err(Error::Config(format!(
                "dataset carries {nf} features; this build extracts \
                 {N_FEATURES} (schema drift — recollect)"
            )));
        }
        let oracle = j
            .get("oracle")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mut samples = Vec::new();
        for (i, js) in j.req_arr("samples")?.iter().enumerate() {
            let chosen = js.req_f64("chosen")? as usize;
            let classes: Vec<u16> = js
                .get("classes")
                .ok_or_else(|| {
                    Error::Config(format!("sample {i} missing 'classes'"))
                })?
                .f64_vec()?
                .into_iter()
                .map(|x| x as u16)
                .collect();
            let feats = js
                .get("feats")
                .ok_or_else(|| {
                    Error::Config(format!("sample {i} missing 'feats'"))
                })?
                .f64_vec()?;
            if classes.is_empty()
                || feats.len() != classes.len() * N_FEATURES
                || chosen >= classes.len()
            {
                return Err(Error::Config(format!(
                    "sample {i} is malformed ({} classes, {} features, \
                     chosen {chosen})",
                    classes.len(),
                    feats.len()
                )));
            }
            samples.push(Sample { chosen: chosen as u32, classes, feats });
        }
        Ok(Dataset { samples, oracle })
    }

    pub fn load(path: &std::path::Path) -> Result<Dataset> {
        Dataset::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// What one collection run hands back: the demonstrations plus the
/// policy-vs-oracle agreement counters (policy rounds only).
#[derive(Debug, Default)]
pub struct Collected {
    pub data: Dataset,
    /// Decisions the *policy* executed (0 in oracle-action rounds).
    pub policy_decisions: u64,
    /// Of those, how many matched the oracle's label.
    pub policy_matches: u64,
}

/// A recording [`Scheduler`]: wraps an oracle, logs (features → chosen
/// PE) demonstrations, and executes either the oracle's actions (round
/// 0) or the current policy's (DAgger rounds).
pub struct Collector {
    oracle: Box<dyn Scheduler>,
    policy: Option<SoftmaxModel>,
    shared: Rc<RefCell<Collected>>,
    max_samples: usize,
    fc: FeatureCtx,
    cands: Vec<(usize, f64)>,
    fins: Vec<f64>,
    avail: Vec<f64>,
}

impl Collector {
    /// Returns the collector plus the shared handle the caller unwraps
    /// after the simulation drops its scheduler (`Rc::try_unwrap`).
    /// `max_samples = 0` makes the collector count-only: agreement
    /// counters still accumulate, but no demonstrations are stored.
    pub fn new(
        oracle: Box<dyn Scheduler>,
        policy: Option<SoftmaxModel>,
        max_samples: usize,
    ) -> (Collector, Rc<RefCell<Collected>>) {
        let shared = Rc::new(RefCell::new(Collected::default()));
        (
            Collector {
                oracle,
                policy,
                shared: Rc::clone(&shared),
                max_samples,
                fc: FeatureCtx::default(),
                cands: Vec::new(),
                fins: Vec::new(),
                avail: Vec::new(),
            },
            shared,
        )
    }
}

impl Scheduler for Collector {
    fn name(&self) -> &str {
        "collect"
    }

    fn schedule(
        &mut self,
        ready: &[ReadyTask],
        ctx: &dyn SchedContext,
    ) -> Vec<Assignment> {
        self.fc.refresh(ctx);
        // The oracle labels the whole epoch from its start-of-epoch view.
        let labels = self.oracle.schedule(ready, ctx);
        // Oracle-action rounds do feature work only to record samples;
        // once the cap is hit the epoch is a plain oracle replay.
        if self.policy.is_none()
            && self.shared.borrow().data.samples.len() >= self.max_samples
        {
            return labels;
        }
        let rt_of: BTreeMap<(usize, usize), &ReadyTask> =
            ready.iter().map(|rt| ((rt.job, rt.task), rt)).collect();
        let pes = ctx.pes();
        let now = ctx.now_us();
        self.avail.clear();
        self.avail.extend(pes.iter().map(|p| p.avail_us));
        let mut out = Vec::with_capacity(labels.len());
        // Walk in the *oracle's commit order* (tasks it left unassigned
        // stay ready, as in a plain oracle run): the virtual-availability
        // trajectory each sample's features see then matches the
        // trajectory the oracle labelled against, instead of re-ordering
        // by ready-list position and mislabelling multi-task epochs.
        for a in &labels {
            let Some(rt) = rt_of.get(&(a.job, a.task)).copied() else {
                continue;
            };
            let oracle_pe = a.pe;
            let best_exec = candidates(rt, ctx, &mut self.cands);
            if self.cands.is_empty() {
                continue;
            }
            let k = self.cands.len();
            let mut classes: Vec<u16> = Vec::with_capacity(k);
            let mut feats = vec![0.0f64; k * N_FEATURES];
            self.fins.clear();
            let mut chosen = usize::MAX;
            for (i, &(pe_id, exec)) in self.cands.iter().enumerate() {
                let snap = &pes[pe_id];
                features_into(
                    rt,
                    ctx,
                    snap,
                    self.avail[pe_id],
                    exec,
                    best_exec,
                    &self.fc,
                    &mut feats[i * N_FEATURES..(i + 1) * N_FEATURES],
                );
                classes.push(snap.class as u16);
                self.fins.push(
                    self.avail[pe_id]
                        .max(ctx.data_ready_us(rt, pe_id))
                        .max(now)
                        + exec,
                );
                if pe_id == oracle_pe {
                    chosen = i;
                }
            }
            if chosen == usize::MAX {
                // Oracle picked a PE outside the candidate view (should
                // not happen — it would be rejected by the kernel too).
                continue;
            }
            // Action: the policy's guarded choice in DAgger rounds, the
            // oracle's label otherwise.
            let act = match &self.policy {
                Some(m) => {
                    let (pick, _) =
                        choose_guarded(m, &classes, &feats, &self.fins);
                    let mut sh = self.shared.borrow_mut();
                    sh.policy_decisions += 1;
                    if pick == chosen {
                        sh.policy_matches += 1;
                    }
                    pick
                }
                None => chosen,
            };
            {
                let mut sh = self.shared.borrow_mut();
                if sh.data.samples.len() < self.max_samples {
                    sh.data.samples.push(Sample {
                        chosen: chosen as u32,
                        classes,
                        feats,
                    });
                }
            }
            let (pe_id, _) = self.cands[act];
            // Advance to the projected finish (data wait included) —
            // the trajectory the next task's features must see.
            self.avail[pe_id] = self.fins[act];
            out.push(Assignment { job: rt.job, task: rt.task, pe: pe_id });
        }
        out
    }

    fn report(&self) -> Vec<String> {
        let sh = self.shared.borrow();
        vec![format!(
            "collect: {} samples (oracle '{}', {} policy decisions)",
            sh.data.len(),
            self.oracle.name(),
            sh.policy_decisions
        )]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::etf::Etf;
    use crate::sched::testutil::{rt, MockCtx};

    fn two_pe_ctx() -> MockCtx {
        let mut ctx = MockCtx::uniform(2, 0.0);
        for t in 0..4 {
            ctx.set_exec(0, t, 0, 10.0);
            ctx.set_exec(0, t, 1, 25.0);
        }
        ctx
    }

    #[test]
    fn oracle_round_records_labels_and_replays_actions() {
        let ctx = two_pe_ctx();
        let (mut coll, shared) =
            Collector::new(Box::new(Etf::new()), None, 1000);
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let mut acts = coll.schedule(&tasks, &ctx);
        // Labels and actions coincide in the oracle round (order within
        // the epoch may differ — compare the task→PE mapping).
        let mut oracle = Etf::new();
        let mut want = oracle.schedule(&tasks, &ctx);
        acts.sort_by_key(|a| (a.job, a.task));
        want.sort_by_key(|a| (a.job, a.task));
        assert_eq!(acts, want);
        let sh = shared.borrow();
        assert_eq!(sh.data.len(), 4);
        assert_eq!(sh.policy_decisions, 0);
        for s in &sh.data.samples {
            assert_eq!(s.classes.len(), 2);
            assert_eq!(s.feats.len(), 2 * N_FEATURES);
            assert!((s.chosen as usize) < s.classes.len());
            assert!(s.feats.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn policy_round_counts_agreement() {
        let ctx = two_pe_ctx();
        // A zero model scores ties -> always picks candidate 0; with the
        // guard wide open its decisions are its own.
        let mut m = SoftmaxModel::zeros(1, "etf");
        m.guard_ratio = 1e9;
        let (mut coll, shared) =
            Collector::new(Box::new(Etf::new()), Some(m), 1000);
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        let acts = coll.schedule(&tasks, &ctx);
        assert_eq!(acts.len(), 4);
        let sh = shared.borrow();
        assert_eq!(sh.policy_decisions, 4);
        assert!(sh.policy_matches <= 4);
        assert_eq!(sh.data.len(), 4);
    }

    #[test]
    fn sample_cap_bounds_memory() {
        let ctx = two_pe_ctx();
        let (mut coll, shared) =
            Collector::new(Box::new(Etf::new()), None, 2);
        let tasks: Vec<_> = (0..4).map(|t| rt(0, t)).collect();
        coll.schedule(&tasks, &ctx);
        assert_eq!(shared.borrow().data.len(), 2);
    }

    #[test]
    fn dataset_json_roundtrip() {
        let mut d = Dataset::default();
        d.oracle = "heft".into();
        d.samples.push(Sample {
            chosen: 1,
            classes: vec![0, 3],
            feats: (0..2 * N_FEATURES).map(|i| i as f64 * 0.5).collect(),
        });
        let j = Json::parse(&d.to_json().to_string_pretty()).unwrap();
        let back = Dataset::from_json(&j).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.oracle, "heft");
        // An unstamped set round-trips too (oracle key omitted).
        let d2 = Dataset::default();
        let j2 = Json::parse(&d2.to_json().to_string()).unwrap();
        assert!(j2.get("oracle").is_none());
        assert_eq!(Dataset::from_json(&j2).unwrap(), d2);
    }

    #[test]
    fn dataset_rejects_malformed_samples() {
        let j = Json::parse(
            r#"{"kind": "ds3r-il-dataset",
                "samples": [{"chosen": 5, "classes": [0, 1],
                             "feats": []}]}"#,
        )
        .unwrap();
        assert!(Dataset::from_json(&j).is_err());
        let j = Json::parse(r#"{"kind": "other", "samples": []}"#).unwrap();
        assert!(Dataset::from_json(&j).is_err());
        // Feature-count drift.
        let j = Json::parse(
            r#"{"kind": "ds3r-il-dataset", "n_features": 2,
                "samples": []}"#,
        )
        .unwrap();
        assert!(Dataset::from_json(&j).is_err());
    }
}
