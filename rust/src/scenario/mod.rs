//! Scenario engine: time-scripted runtime events for dynamic workloads,
//! faults, and environment changes.
//!
//! A [`Scenario`] is a declarative, JSON-loadable timeline of runtime
//! [`Action`]s that the discrete-event loop executes alongside task
//! events (`sim::queue::Event::Scenario`).  It turns a static simulation
//! point — one injection rate, one app mix, one ambient temperature, a
//! fixed PE set — into a *dynamic* run: workload bursts, thermal events,
//! resource loss, policy changes.  Dynamic resource management only
//! matters under changing conditions (DS3 journal version, CEDR); this
//! module is how DS3R scripts those conditions reproducibly.
//!
//! ## Event vocabulary
//!
//! | action            | effect                                          |
//! |-------------------|-------------------------------------------------|
//! | `set-rate`        | step the aggregate injection rate (jobs/ms)     |
//! | `ramp-rate`       | linear injection-rate ramp over a window        |
//! | `set-app-weights` | switch the application mix weights              |
//! | `set-ambient`     | step the ambient temperature (°C)               |
//! | `pe-fail`         | PE fault: finishes its in-flight task, then     |
//! |                   | accepts no work (queued tasks are re-queued)    |
//! | `pe-restore`      | hotplug the PE back in                          |
//! | `set-power-cap`   | change/remove the DTPM SoC power budget (W)     |
//! | `set-scheduler`   | hot-swap the scheduler by registry name         |
//!
//! ## JSON schema
//!
//! ```json
//! {
//!   "name": "pe-failure",
//!   "description": "optional free text",
//!   "events": [
//!     {"at_us": 0,      "action": "set-rate",        "per_ms": 2.0},
//!     {"at_us": 50000,  "action": "ramp-rate",       "to_per_ms": 8.0,
//!                                                    "over_us": 25000},
//!     {"at_us": 60000,  "action": "set-app-weights", "weights": [1, 3]},
//!     {"at_us": 70000,  "action": "set-ambient",     "t_c": 45.0},
//!     {"at_us": 80000,  "action": "pe-fail",         "pe": 10},
//!     {"at_us": 90000,  "action": "pe-restore",      "pe": 10},
//!     {"at_us": 100000, "action": "set-power-cap",   "watts": 5.0},
//!     {"at_us": 110000, "action": "set-power-cap"},
//!     {"at_us": 120000, "action": "set-scheduler",   "scheduler": "heft"}
//!   ]
//! }
//! ```
//!
//! `set-power-cap` without `watts` removes the cap.  Timestamps must be
//! non-negative and non-decreasing; equal timestamps execute in listing
//! order (the event queue's (time, sequence) total order makes the whole
//! run deterministic).
//!
//! Each listed event opens a new *phase*; [`crate::stats::SimReport`]
//! reports latency/energy/temperature per phase so the effect of every
//! timeline step is visible in one run.  A library of named presets
//! lives in [`presets`].

pub mod presets;

use crate::platform::Platform;
use crate::util::json::Json;
use crate::{Error, Result};

/// Number of `set-rate` sub-steps a `ramp-rate` expands into.
pub const RAMP_STEPS: usize = 8;

/// One runtime action.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Step the aggregate injection rate (jobs per millisecond).
    SetRate { per_ms: f64 },
    /// Linear injection-rate ramp from the rate in force at `at_us` to
    /// `to_per_ms` over `over_us` (expanded into [`RAMP_STEPS`] steps).
    RampRate { to_per_ms: f64, over_us: f64 },
    /// Switch the application-mix weights (length must match the
    /// workload's app count).
    SetAppWeights { weights: Vec<f64> },
    /// Step the ambient temperature (absolute °C).
    SetAmbient { t_c: f64 },
    /// Fail a PE: it finishes its in-flight task, its committed queue is
    /// re-queued for rescheduling, and it accepts no work until restored.
    PeFail { pe: usize },
    /// Restore a failed PE (hotplug).
    PeRestore { pe: usize },
    /// Set (`Some`) or remove (`None`) the DTPM SoC power cap.
    SetPowerCap { watts: Option<f64> },
    /// Hot-swap the scheduler (registry name, see `sched::create`).
    SetScheduler { name: String },
}

impl Action {
    /// Compact label used for phase names and listings.
    pub fn label(&self) -> String {
        match self {
            Action::SetRate { per_ms } => format!("rate={per_ms}/ms"),
            Action::RampRate { to_per_ms, .. } => {
                format!("ramp->{to_per_ms}/ms")
            }
            Action::SetAppWeights { .. } => "app-mix".into(),
            Action::SetAmbient { t_c } => format!("ambient={t_c}C"),
            Action::PeFail { pe } => format!("pe{pe}-fail"),
            Action::PeRestore { pe } => format!("pe{pe}-restore"),
            Action::SetPowerCap { watts: Some(w) } => format!("cap={w}W"),
            Action::SetPowerCap { watts: None } => "cap-off".into(),
            Action::SetScheduler { name } => format!("sched={name}"),
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            Action::SetRate { per_ms } => {
                j.set("action", Json::Str("set-rate".into()))
                    .set("per_ms", Json::Num(*per_ms));
            }
            Action::RampRate { to_per_ms, over_us } => {
                j.set("action", Json::Str("ramp-rate".into()))
                    .set("to_per_ms", Json::Num(*to_per_ms))
                    .set("over_us", Json::Num(*over_us));
            }
            Action::SetAppWeights { weights } => {
                j.set("action", Json::Str("set-app-weights".into()))
                    .set(
                        "weights",
                        Json::Arr(
                            weights.iter().map(|&w| Json::Num(w)).collect(),
                        ),
                    );
            }
            Action::SetAmbient { t_c } => {
                j.set("action", Json::Str("set-ambient".into()))
                    .set("t_c", Json::Num(*t_c));
            }
            Action::PeFail { pe } => {
                j.set("action", Json::Str("pe-fail".into()))
                    .set("pe", Json::Num(*pe as f64));
            }
            Action::PeRestore { pe } => {
                j.set("action", Json::Str("pe-restore".into()))
                    .set("pe", Json::Num(*pe as f64));
            }
            Action::SetPowerCap { watts } => {
                j.set("action", Json::Str("set-power-cap".into()));
                if let Some(w) = watts {
                    j.set("watts", Json::Num(*w));
                }
            }
            Action::SetScheduler { name } => {
                j.set("action", Json::Str("set-scheduler".into()))
                    .set("scheduler", Json::Str(name.clone()));
            }
        }
        j
    }

    fn from_json(j: &Json) -> Result<Action> {
        let kind = j.req_str("action")?;
        match kind {
            "set-rate" => Ok(Action::SetRate { per_ms: j.req_f64("per_ms")? }),
            "ramp-rate" => Ok(Action::RampRate {
                to_per_ms: j.req_f64("to_per_ms")?,
                over_us: j.req_f64("over_us")?,
            }),
            "set-app-weights" => Ok(Action::SetAppWeights {
                weights: j
                    .get("weights")
                    .ok_or_else(|| {
                        Error::Config(
                            "set-app-weights needs 'weights'".into(),
                        )
                    })?
                    .f64_vec()
                    .map_err(|_| {
                        Error::Config(
                            "set-app-weights 'weights' must be numbers"
                                .into(),
                        )
                    })?,
            }),
            "set-ambient" => {
                Ok(Action::SetAmbient { t_c: j.req_f64("t_c")? })
            }
            "pe-fail" => Ok(Action::PeFail {
                pe: j.req_f64("pe")? as usize,
            }),
            "pe-restore" => Ok(Action::PeRestore {
                pe: j.req_f64("pe")? as usize,
            }),
            "set-power-cap" => Ok(Action::SetPowerCap {
                watts: j.get("watts").and_then(Json::as_f64),
            }),
            "set-scheduler" => Ok(Action::SetScheduler {
                name: j.req_str("scheduler")?.to_string(),
            }),
            other => Err(Error::Config(format!(
                "unknown scenario action '{other}' (set-rate, ramp-rate, \
                 set-app-weights, set-ambient, pe-fail, pe-restore, \
                 set-power-cap, set-scheduler)"
            ))),
        }
    }
}

/// One timeline entry: `action` executes at simulated time `at_us`.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioEvent {
    pub at_us: f64,
    pub action: Action,
}

/// A named, validated timeline of runtime events.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub events: Vec<ScenarioEvent>,
}

impl Scenario {
    pub fn new(
        name: impl Into<String>,
        description: impl Into<String>,
    ) -> Scenario {
        Scenario {
            name: name.into(),
            description: description.into(),
            events: Vec::new(),
        }
    }

    /// Builder: append an event (keep timestamps non-decreasing).
    pub fn event(mut self, at_us: f64, action: Action) -> Scenario {
        self.events.push(ScenarioEvent { at_us, action });
        self
    }

    /// A copy with event `idx` deleted — the primitive the fuzz
    /// shrinker ([`crate::fuzz::tournament`]) greedily applies while a
    /// candidate still reproduces its oracle violation.  Deletion
    /// preserves timestamp order and only ever *shrinks* ramp windows,
    /// so a valid scenario stays valid; the shrinker still re-validates
    /// each candidate defensively.
    pub fn without_event(&self, idx: usize) -> Scenario {
        let mut events = self.events.clone();
        events.remove(idx);
        Scenario {
            name: self.name.clone(),
            description: self.description.clone(),
            events,
        }
    }

    /// Platform-independent validation: timestamps and action payloads.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(Error::Config("scenario has no name".into()));
        }
        let mut last = 0.0f64;
        // End of the latest ramp window: rate events inside it would be
        // silently overridden by the ramp's later interpolation steps.
        let mut ramp_until = f64::NEG_INFINITY;
        for (i, ev) in self.events.iter().enumerate() {
            if !ev.at_us.is_finite() || ev.at_us < 0.0 {
                return Err(Error::Config(format!(
                    "scenario '{}': event {i} has negative or non-finite \
                     time {}",
                    self.name, ev.at_us
                )));
            }
            if ev.at_us < last {
                return Err(Error::Config(format!(
                    "scenario '{}': timeline out of order at event {i} \
                     ({} us after {} us)",
                    self.name, ev.at_us, last
                )));
            }
            last = ev.at_us;
            if matches!(
                ev.action,
                Action::SetRate { .. } | Action::RampRate { .. }
            ) && ev.at_us < ramp_until
            {
                return Err(Error::Config(format!(
                    "scenario '{}': rate event {i} at {} us falls inside \
                     an active ramp-rate window (ends {} us)",
                    self.name, ev.at_us, ramp_until
                )));
            }
            match &ev.action {
                Action::SetRate { per_ms } => {
                    if *per_ms <= 0.0 || !per_ms.is_finite() {
                        return Err(Error::Config(format!(
                            "scenario '{}': set-rate {per_ms} must be > 0",
                            self.name
                        )));
                    }
                }
                Action::RampRate { to_per_ms, over_us } => {
                    if *to_per_ms <= 0.0 || !to_per_ms.is_finite() {
                        return Err(Error::Config(format!(
                            "scenario '{}': ramp-rate target {to_per_ms} \
                             must be > 0",
                            self.name
                        )));
                    }
                    if *over_us <= 0.0 || !over_us.is_finite() {
                        return Err(Error::Config(format!(
                            "scenario '{}': ramp-rate over_us {over_us} \
                             must be > 0",
                            self.name
                        )));
                    }
                    ramp_until = ramp_until.max(ev.at_us + over_us);
                }
                Action::SetAppWeights { weights } => {
                    if weights.is_empty()
                        || weights.iter().any(|w| *w < 0.0 || !w.is_finite())
                        || weights.iter().sum::<f64>() <= 0.0
                    {
                        return Err(Error::Config(format!(
                            "scenario '{}': set-app-weights needs \
                             non-negative weights with a positive sum",
                            self.name
                        )));
                    }
                }
                Action::SetAmbient { t_c } => {
                    if !(-55.0..=150.0).contains(t_c) {
                        return Err(Error::Config(format!(
                            "scenario '{}': ambient {t_c} °C outside \
                             [-55, 150]",
                            self.name
                        )));
                    }
                }
                Action::SetPowerCap { watts: Some(w) } => {
                    if *w <= 0.0 || !w.is_finite() {
                        return Err(Error::Config(format!(
                            "scenario '{}': power cap {w} W must be > 0",
                            self.name
                        )));
                    }
                }
                Action::SetPowerCap { watts: None } => {}
                Action::SetScheduler { name } => {
                    if name.is_empty() {
                        return Err(Error::Config(format!(
                            "scenario '{}': set-scheduler needs a name",
                            self.name
                        )));
                    }
                }
                Action::PeFail { .. } | Action::PeRestore { .. } => {}
            }
        }
        Ok(())
    }

    /// Platform/workload-dependent validation: PE ids in range, app-mix
    /// weight vectors matching the workload size.
    pub fn validate_for(
        &self,
        platform: &Platform,
        n_apps: usize,
    ) -> Result<()> {
        for ev in &self.events {
            match &ev.action {
                Action::PeFail { pe } | Action::PeRestore { pe } => {
                    if *pe >= platform.n_pes() {
                        return Err(Error::Config(format!(
                            "scenario '{}' references unknown PE id {pe} \
                             (platform '{}' has {} PEs)",
                            self.name,
                            platform.name,
                            platform.n_pes()
                        )));
                    }
                }
                Action::SetAppWeights { weights } => {
                    if weights.len() != n_apps {
                        return Err(Error::Config(format!(
                            "scenario '{}': set-app-weights has {} \
                             weights, workload has {n_apps} apps",
                            self.name,
                            weights.len()
                        )));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Scheduler names this scenario hot-swaps to (build-time dry runs).
    pub fn scheduler_names(&self) -> Vec<&str> {
        self.events
            .iter()
            .filter_map(|ev| match &ev.action {
                Action::SetScheduler { name } => Some(name.as_str()),
                _ => None,
            })
            .collect()
    }

    /// Expand the timeline into the executable form: ramps become
    /// [`RAMP_STEPS`] interpolated `set-rate` steps; the first event of
    /// each distinct timestamp carries the phase label (joined across
    /// simultaneous events) so per-phase stats have one phase per
    /// timeline step, not one per co-timed action.
    pub fn compile(&self, initial_rate_per_ms: f64) -> Vec<CompiledEvent> {
        let mut out: Vec<CompiledEvent> = Vec::new();
        let mut cur_rate = initial_rate_per_ms;
        let mut i = 0;
        while i < self.events.len() {
            // Group events sharing this timestamp.
            let t = self.events[i].at_us;
            let mut j = i;
            while j < self.events.len() && self.events[j].at_us == t {
                j += 1;
            }
            let label = self.events[i..j]
                .iter()
                .map(|ev| ev.action.label())
                .collect::<Vec<_>>()
                .join("+");
            let mut first = true;
            for ev in &self.events[i..j] {
                let phase_label = first.then(|| label.clone());
                first = false;
                match &ev.action {
                    Action::RampRate { to_per_ms, over_us } => {
                        // Labeled no-op anchor at the ramp start, then
                        // interpolated steps (no extra phases).
                        out.push(CompiledEvent {
                            at_us: t,
                            action: Action::SetRate { per_ms: cur_rate },
                            phase_label,
                        });
                        for s in 1..=RAMP_STEPS {
                            let f = s as f64 / RAMP_STEPS as f64;
                            out.push(CompiledEvent {
                                at_us: t + over_us * f,
                                action: Action::SetRate {
                                    per_ms: cur_rate
                                        + (to_per_ms - cur_rate) * f,
                                },
                                phase_label: None,
                            });
                        }
                        cur_rate = *to_per_ms;
                    }
                    other => {
                        if let Action::SetRate { per_ms } = other {
                            cur_rate = *per_ms;
                        }
                        out.push(CompiledEvent {
                            at_us: t,
                            action: other.clone(),
                            phase_label,
                        });
                    }
                }
            }
            i = j;
        }
        out
    }

    // ---- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        if !self.description.is_empty() {
            j.set("description", Json::Str(self.description.clone()));
        }
        j.set(
            "events",
            Json::Arr(
                self.events
                    .iter()
                    .map(|ev| {
                        let mut je = ev.action.to_json();
                        je.set("at_us", Json::Num(ev.at_us));
                        je
                    })
                    .collect(),
            ),
        );
        j
    }

    /// Parse and validate a scenario (platform-independent checks only;
    /// the simulation build validates PE ids and weight lengths).
    pub fn from_json(j: &Json) -> Result<Scenario> {
        let name = j.req_str("name")?.to_string();
        let description = j
            .get("description")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mut events = Vec::new();
        for je in j.req_arr("events")? {
            events.push(ScenarioEvent {
                at_us: je.req_f64("at_us")?,
                action: Action::from_json(je)?,
            });
        }
        let s = Scenario { name, description, events };
        s.validate()?;
        Ok(s)
    }

    pub fn load(path: &std::path::Path) -> Result<Scenario> {
        Scenario::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// One executable timeline entry (ramps pre-expanded).  Events carrying a
/// `phase_label` open a new stats phase when they fire.
#[derive(Debug, Clone)]
pub struct CompiledEvent {
    pub at_us: f64,
    pub action: Action,
    pub phase_label: Option<String>,
}

/// Resolve a scenario by preset name, or load a JSON scenario file
/// (anything containing a path separator or ending in `.json`).
pub fn resolve(name_or_path: &str) -> Result<Scenario> {
    if let Some(s) = presets::by_name(name_or_path) {
        return Ok(s);
    }
    if name_or_path.ends_with(".json") || name_or_path.contains('/') {
        return Scenario::load(std::path::Path::new(name_or_path));
    }
    Err(Error::Config(format!(
        "unknown scenario '{name_or_path}' (presets: {}; or a .json file)",
        presets::names().join(", ")
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn demo() -> Scenario {
        Scenario::new("demo", "a bit of everything")
            .event(0.0, Action::SetRate { per_ms: 2.0 })
            .event(
                1000.0,
                Action::RampRate { to_per_ms: 8.0, over_us: 400.0 },
            )
            .event(2000.0, Action::SetAppWeights { weights: vec![1.0, 3.0] })
            .event(3000.0, Action::SetAmbient { t_c: 45.0 })
            .event(4000.0, Action::PeFail { pe: 10 })
            .event(5000.0, Action::PeRestore { pe: 10 })
            .event(6000.0, Action::SetPowerCap { watts: Some(5.0) })
            .event(7000.0, Action::SetPowerCap { watts: None })
            .event(
                8000.0,
                Action::SetScheduler { name: "heft".into() },
            )
    }

    #[test]
    fn json_roundtrip_parse_serialize_parse() {
        let s = demo();
        s.validate().unwrap();
        let j = s.to_json();
        let s2 = Scenario::from_json(&j).unwrap();
        assert_eq!(s, s2);
        // Text-level stability: serialize -> parse -> serialize.
        let text = j.to_string_pretty();
        let s3 = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(s, s3);
        assert_eq!(s3.to_json().to_string_pretty(), text);
    }

    #[test]
    fn validation_rejects_negative_time() {
        let s = Scenario::new("bad", "")
            .event(-1.0, Action::SetRate { per_ms: 1.0 });
        let msg = format!("{}", s.validate().unwrap_err());
        assert!(msg.contains("negative"), "{msg}");
    }

    #[test]
    fn validation_rejects_out_of_order_timeline() {
        let s = Scenario::new("bad", "")
            .event(100.0, Action::SetRate { per_ms: 1.0 })
            .event(50.0, Action::SetRate { per_ms: 2.0 });
        let msg = format!("{}", s.validate().unwrap_err());
        assert!(msg.contains("out of order"), "{msg}");
    }

    #[test]
    fn validation_rejects_bad_payloads() {
        for s in [
            Scenario::new("x", "").event(0.0, Action::SetRate { per_ms: 0.0 }),
            Scenario::new("x", "").event(
                0.0,
                Action::RampRate { to_per_ms: 2.0, over_us: 0.0 },
            ),
            Scenario::new("x", "")
                .event(0.0, Action::SetAppWeights { weights: vec![] }),
            Scenario::new("x", "").event(
                0.0,
                Action::SetAppWeights { weights: vec![0.0, 0.0] },
            ),
            Scenario::new("x", "")
                .event(0.0, Action::SetAmbient { t_c: 500.0 }),
            Scenario::new("x", "")
                .event(0.0, Action::SetPowerCap { watts: Some(-1.0) }),
            Scenario::new("x", "")
                .event(0.0, Action::SetScheduler { name: "".into() }),
        ] {
            assert!(s.validate().is_err(), "accepted: {s:?}");
        }
    }

    #[test]
    fn validation_rejects_rate_event_inside_ramp_window() {
        // A rate event inside an active ramp would be silently undone
        // by the ramp's pre-expanded later steps — reject it.
        let s = Scenario::new("overlap", "")
            .event(
                0.0,
                Action::RampRate { to_per_ms: 8.0, over_us: 100_000.0 },
            )
            .event(50_000.0, Action::SetRate { per_ms: 1.0 });
        let msg = format!("{}", s.validate().unwrap_err());
        assert!(msg.contains("ramp-rate window"), "{msg}");

        // Non-rate events inside the window are fine (a fault during a
        // ramp is a legitimate scenario)...
        let ok = Scenario::new("ok", "")
            .event(
                0.0,
                Action::RampRate { to_per_ms: 8.0, over_us: 100_000.0 },
            )
            .event(50_000.0, Action::PeFail { pe: 0 });
        ok.validate().unwrap();
        // ...and a rate event at/after the ramp end is too.
        let ok2 = Scenario::new("ok2", "")
            .event(
                0.0,
                Action::RampRate { to_per_ms: 8.0, over_us: 100_000.0 },
            )
            .event(100_000.0, Action::SetRate { per_ms: 1.0 });
        ok2.validate().unwrap();
    }

    #[test]
    fn platform_validation_rejects_unknown_pe() {
        let p = Platform::table2_soc();
        let ok = Scenario::new("ok", "")
            .event(0.0, Action::PeFail { pe: p.n_pes() - 1 });
        ok.validate_for(&p, 1).unwrap();
        let bad = Scenario::new("bad", "")
            .event(0.0, Action::PeFail { pe: p.n_pes() });
        let msg = format!("{}", bad.validate_for(&p, 1).unwrap_err());
        assert!(msg.contains("unknown PE id"), "{msg}");
    }

    #[test]
    fn platform_validation_rejects_weight_mismatch() {
        let p = Platform::table2_soc();
        let s = Scenario::new("w", "")
            .event(0.0, Action::SetAppWeights { weights: vec![1.0, 2.0] });
        assert!(s.validate_for(&p, 2).is_ok());
        assert!(s.validate_for(&p, 3).is_err());
    }

    #[test]
    fn unknown_action_rejected_with_context() {
        let j = Json::parse(
            r#"{"name": "x", "events": [{"at_us": 0, "action": "warp"}]}"#,
        )
        .unwrap();
        let msg = format!("{}", Scenario::from_json(&j).unwrap_err());
        assert!(msg.contains("unknown scenario action"), "{msg}");
    }

    #[test]
    fn ramp_expands_to_interpolated_steps() {
        let s = Scenario::new("r", "").event(
            1000.0,
            Action::RampRate { to_per_ms: 9.0, over_us: 800.0 },
        );
        let c = s.compile(1.0);
        // Anchor + RAMP_STEPS interpolated steps.
        assert_eq!(c.len(), 1 + RAMP_STEPS);
        assert!(c[0].phase_label.is_some());
        assert!(c[1..].iter().all(|e| e.phase_label.is_none()));
        match &c[0].action {
            Action::SetRate { per_ms } => assert_eq!(*per_ms, 1.0),
            other => panic!("{other:?}"),
        }
        match &c[RAMP_STEPS].action {
            Action::SetRate { per_ms } => {
                assert!((per_ms - 9.0).abs() < 1e-12)
            }
            other => panic!("{other:?}"),
        }
        assert!((c[RAMP_STEPS].at_us - 1800.0).abs() < 1e-9);
        // Midpoint is halfway up.
        match &c[RAMP_STEPS / 2].action {
            Action::SetRate { per_ms } => {
                assert!((per_ms - 5.0).abs() < 1e-9)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn simultaneous_events_share_one_phase() {
        let s = Scenario::new("m", "")
            .event(100.0, Action::PeFail { pe: 0 })
            .event(100.0, Action::PeFail { pe: 1 })
            .event(200.0, Action::PeRestore { pe: 0 });
        let c = s.compile(1.0);
        assert_eq!(c.len(), 3);
        assert_eq!(
            c[0].phase_label.as_deref(),
            Some("pe0-fail+pe1-fail")
        );
        assert!(c[1].phase_label.is_none());
        assert_eq!(c[2].phase_label.as_deref(), Some("pe0-restore"));
    }

    #[test]
    fn resolve_finds_presets_and_rejects_unknown() {
        for name in presets::names() {
            let s = resolve(name).unwrap();
            assert_eq!(&s.name, name);
            s.validate().unwrap();
        }
        assert!(resolve("no-such-scenario").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("ds3r-scenario-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("demo.json");
        let s = demo();
        s.save(&path).unwrap();
        let back = Scenario::load(&path).unwrap();
        assert_eq!(s, back);
        // resolve() accepts explicit paths.
        let via = resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(s, via);
        std::fs::remove_dir_all(&dir).ok();
    }
}
