//! DSSoC design-space exploration: sweep the accelerator provisioning of
//! the SoC (how many FFT engines? how many scrambler engines?) under a
//! mixed wireless workload — the paper's headline use case: "rapid ...
//! exploration of DSSoCs" / "sweeping the configuration space to
//! determine the most suitable scheduling algorithm for a given SoC
//! architecture".
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use ds3r::app::suite::{self, WifiParams};
use ds3r::config::SimConfig;
use ds3r::platform::{
    Cluster, NocParams, Pe, Platform, ThermalFloorplan,
};
use ds3r::sim::Simulation;
use ds3r::util::plot;

/// Build a Table-2-style SoC with a configurable accelerator mix.
fn custom_soc(n_fft: usize, n_scr: usize) -> Platform {
    let base = Platform::table2_soc();
    let classes = base.classes.clone();
    let fp = ThermalFloorplan {
        node_names: base.floorplan.node_names.clone(),
        capacitance: base.floorplan.capacitance.clone(),
        g_amb: base.floorplan.g_amb.clone(),
        couplings: base.floorplan.couplings.clone(),
    };
    // Lay PEs on a mesh big enough for the largest config.
    let mesh = NocParams { mesh_x: 6, mesh_y: 4, ..NocParams::default() };
    let mut pes = Vec::new();
    let mut clusters = Vec::new();
    let mut place = |name: &str,
                     class: usize,
                     node: usize,
                     count: usize,
                     row: usize,
                     pes: &mut Vec<Pe>,
                     clusters: &mut Vec<Cluster>| {
        let id = clusters.len();
        let mut pe_ids = Vec::new();
        for i in 0..count {
            let pe_id = pes.len();
            pes.push(Pe {
                id: pe_id,
                class,
                cluster: id,
                name: format!("{name}-{i}"),
                x: i % 6,
                y: row - i / 6, // wrap to the row below if > 6 wide
            });
            pe_ids.push(pe_id);
        }
        clusters.push(Cluster {
            id,
            name: name.into(),
            class,
            pe_ids,
            thermal_node: node,
        });
    };
    place("A15", 0, 0, 4, 3, &mut pes, &mut clusters);
    place("A7", 1, 1, 4, 2, &mut pes, &mut clusters);
    place("ACC_SCR", 2, 2, n_scr, 1, &mut pes, &mut clusters);
    place("ACC_FFT", 3, 3, n_fft, 0, &mut pes, &mut clusters);
    Platform::new(
        format!("dse-{n_fft}fft-{n_scr}scr"),
        classes,
        pes,
        clusters,
        mesh,
        fp,
    )
    .expect("custom SoC valid")
}

fn main() {
    let apps = vec![
        suite::wifi_tx(WifiParams::default()),
        suite::wifi_rx(WifiParams { symbols: 4 }),
    ];

    println!("Design-space exploration: FFT-engine provisioning under a");
    println!("WiFi TX+RX mix at 4 jobs/ms (ETF scheduler)\n");

    let mut rows = Vec::new();
    let mut latency = plot::Series::new("avg latency us");
    for n_fft in [1, 2, 3, 4, 6] {
        let platform = custom_soc(n_fft, 2);
        let mut cfg = SimConfig::default();
        cfg.scheduler = "etf".into();
        cfg.injection_rate_per_ms = 4.0;
        cfg.max_jobs = 600;
        cfg.warmup_jobs = 60;
        cfg.max_sim_us = 4_000_000.0;
        let r = Simulation::build(&platform, &apps, &cfg)
            .expect("valid")
            .run();
        rows.push(vec![
            format!("{n_fft}"),
            format!("{:.1}", r.avg_job_latency_us()),
            format!("{:.3}", r.throughput_jobs_per_ms()),
            format!("{:.2}", r.energy_per_job_mj()),
            format!("{:.1}", r.peak_temp_c),
        ]);
        latency.push(n_fft as f64, r.avg_job_latency_us());
    }
    println!(
        "{}",
        plot::ascii_table(
            &["# FFT acc", "avg us", "thru/ms", "mJ/job", "peak C"],
            &rows
        )
    );
    println!(
        "{}",
        plot::ascii_chart(
            "latency vs FFT-engine count",
            "# FFT engines",
            "us",
            &[latency],
            60,
            14
        )
    );
    println!(
        "The knee identifies the smallest accelerator budget that meets\n\
         the latency target — the DSSoC provisioning decision the paper's\n\
         framework is built to answer."
    );
}
