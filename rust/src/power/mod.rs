//! Analytical power model and energy accounting ([Bhat et al. 2018]).
//!
//! Per PE:
//!
//! ```text
//!   P_dyn  = ceff * V^2 * f_mhz * utilization        (W)
//!   P_leak = k1 * V * exp(k2 * T)                    (W, T in °C)
//! ```
//!
//! The simulation kernel integrates power over DTPM epochs into energy;
//! per-candidate batched evaluation (for DVFS design-space exploration)
//! goes through the AOT Pallas artifact (see `thermal::XlaThermal`), with
//! this module as the scalar reference implementation.

use crate::platform::{Opp, PeClass, Platform};

/// Dynamic power of one PE (W).
#[inline]
pub fn p_dynamic(class: &PeClass, opp: Opp, utilization: f64) -> f64 {
    debug_assert!((0.0..=1.0 + 1e-9).contains(&utilization));
    class.ceff * opp.volt * opp.volt * opp.freq_mhz * utilization
}

/// Leakage power of one PE (W) at temperature `t_c` (°C).
#[inline]
pub fn p_leakage(class: &PeClass, volt: f64, t_c: f64) -> f64 {
    class.leak_k1 * volt * (class.leak_k2 * t_c).exp()
}

/// Per-epoch energy bookkeeping for the whole platform.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    /// Joules accumulated per PE.
    pub energy_j: Vec<f64>,
    /// Busy time accumulated per PE (µs), for utilization reports.
    pub busy_us: Vec<f64>,
    /// Total simulated time covered so far (µs).
    pub elapsed_us: f64,
}

impl EnergyMeter {
    pub fn new(n_pes: usize) -> Self {
        EnergyMeter {
            energy_j: vec![0.0; n_pes],
            busy_us: vec![0.0; n_pes],
            elapsed_us: 0.0,
        }
    }

    /// Rewind to the fresh `new(n_pes)` state, reusing the per-PE
    /// buffers (the simulation worker's reset path).
    pub fn reset(&mut self, n_pes: usize) {
        self.energy_j.clear();
        self.energy_j.resize(n_pes, 0.0);
        self.busy_us.clear();
        self.busy_us.resize(n_pes, 0.0);
        self.elapsed_us = 0.0;
    }

    /// Integrate one epoch: `powers[pe]` in W over `dt_us` microseconds.
    pub fn add_epoch(&mut self, powers: &[f64], busy_us: &[f64], dt_us: f64) {
        debug_assert_eq!(powers.len(), self.energy_j.len());
        for (e, p) in self.energy_j.iter_mut().zip(powers) {
            *e += p * dt_us * 1e-6; // W * s
        }
        for (b, add) in self.busy_us.iter_mut().zip(busy_us) {
            *b += add;
        }
        self.elapsed_us += dt_us;
    }

    pub fn total_energy_j(&self) -> f64 {
        self.energy_j.iter().sum()
    }

    /// Mean utilization of a PE over the whole run, in [0, 1].
    pub fn utilization(&self, pe: usize) -> f64 {
        if self.elapsed_us <= 0.0 {
            0.0
        } else {
            (self.busy_us[pe] / self.elapsed_us).min(1.0)
        }
    }

    /// Average platform power (W) over the run.
    pub fn avg_power_w(&self) -> f64 {
        if self.elapsed_us <= 0.0 {
            0.0
        } else {
            self.total_energy_j() / (self.elapsed_us * 1e-6)
        }
    }
}

/// Compute per-PE power for one epoch given utilizations, the cluster
/// OPPs currently in force, and PE temperatures.  Scalar (non-batched)
/// reference path; the batched XLA path must agree with this to 1e-4
/// (asserted by `thermal::tests` and integration tests).
pub fn epoch_power(
    platform: &Platform,
    cluster_opp: &[Opp],
    utilization: &[f64],
    t_pe: &[f64],
) -> Vec<f64> {
    let mut out = Vec::with_capacity(platform.n_pes());
    epoch_power_into(platform, cluster_opp, utilization, t_pe, &mut out);
    out
}

/// Allocation-free variant of [`epoch_power`] used on the simulation
/// hot path (the lazy integration lane replays many epochs per flush).
/// Identical arithmetic, writes into the reused `out` buffer.
pub fn epoch_power_into(
    platform: &Platform,
    cluster_opp: &[Opp],
    utilization: &[f64],
    t_pe: &[f64],
    out: &mut Vec<f64>,
) {
    out.clear();
    for pe in &platform.pes {
        let class = &platform.classes[pe.class];
        let opp = cluster_opp[pe.cluster];
        let p = p_dynamic(class, opp, utilization[pe.id])
            + p_leakage(class, opp.volt, t_pe[pe.id]);
        out.push(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    #[test]
    fn dynamic_power_scales_quadratically_with_voltage() {
        let p = Platform::table2_soc();
        let big = &p.classes[p.class_index("A15").unwrap()];
        let lo = big.min_opp();
        let hi = big.max_opp();
        let p_lo = p_dynamic(big, lo, 1.0);
        let p_hi = p_dynamic(big, hi, 1.0);
        let expect = (hi.volt / lo.volt).powi(2) * (hi.freq_mhz / lo.freq_mhz);
        assert!(((p_hi / p_lo) - expect).abs() < 1e-9);
    }

    #[test]
    fn idle_pe_draws_only_leakage() {
        let p = Platform::table2_soc();
        let big = &p.classes[p.class_index("A15").unwrap()];
        assert_eq!(p_dynamic(big, big.max_opp(), 0.0), 0.0);
        assert!(p_leakage(big, big.max_opp().volt, 50.0) > 0.0);
    }

    #[test]
    fn leakage_grows_with_temperature() {
        let p = Platform::table2_soc();
        let big = &p.classes[p.class_index("A15").unwrap()];
        let cold = p_leakage(big, 1.2, 25.0);
        let hot = p_leakage(big, 1.2, 85.0);
        assert!(hot > cold * 2.0, "hot={hot} cold={cold}");
    }

    #[test]
    fn energy_meter_integrates() {
        let mut m = EnergyMeter::new(2);
        // 2 W and 1 W for 1 second (1e6 µs).
        m.add_epoch(&[2.0, 1.0], &[5e5, 1e6], 1e6);
        assert!((m.energy_j[0] - 2.0).abs() < 1e-9);
        assert!((m.energy_j[1] - 1.0).abs() < 1e-9);
        assert!((m.total_energy_j() - 3.0).abs() < 1e-9);
        assert!((m.utilization(0) - 0.5).abs() < 1e-9);
        assert!((m.utilization(1) - 1.0).abs() < 1e-9);
        assert!((m.avg_power_w() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn epoch_power_into_matches_allocating_path() {
        let p = Platform::table2_soc();
        let opps: Vec<_> =
            p.clusters.iter().map(|c| p.classes[c.class].max_opp()).collect();
        let util: Vec<f64> =
            (0..p.n_pes()).map(|i| (i as f64 / 14.0).min(1.0)).collect();
        let temps: Vec<f64> =
            (0..p.n_pes()).map(|i| 30.0 + i as f64).collect();
        let a = epoch_power(&p, &opps, &util, &temps);
        let mut b = vec![999.0; 3]; // stale garbage must be cleared
        epoch_power_into(&p, &opps, &util, &temps, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn epoch_power_covers_all_pes() {
        let p = Platform::table2_soc();
        let opps: Vec<_> =
            p.clusters.iter().map(|c| p.classes[c.class].max_opp()).collect();
        let util = vec![1.0; p.n_pes()];
        let temps = vec![45.0; p.n_pes()];
        let powers = epoch_power(&p, &opps, &util, &temps);
        assert_eq!(powers.len(), p.n_pes());
        assert!(powers.iter().all(|&w| w > 0.0));
        // Fully loaded Table-2 SoC should land in a plausible envelope
        // for a big.LITTLE part + accelerators: ~6-12 W.
        let total: f64 = powers.iter().sum();
        assert!((5.0..15.0).contains(&total), "total={total} W");
    }
}
