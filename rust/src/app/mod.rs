//! Application model: DAG-based programs (Figure 2 of the paper).
//!
//! An application is a directed acyclic graph of tasks.  Each task carries
//! its *execution-time profile*: expected latency (µs, at the class's
//! nominal frequency) on every PE class that supports it — the per-task
//! rows of Table 1.  Jobs are instances of an [`AppGraph`] injected by the
//! job generator.
//!
//! The paper's five-application benchmark suite (WiFi TX/RX, low-power
//! single-carrier TX/RX, range detection, pulse Doppler) lives in
//! [`suite`].

pub mod suite;

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::{Error, Result};

/// One task in an application DAG.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Task name, unique within the app (e.g. "interleaver-3").
    pub name: String,
    /// Expected execution latency per supporting PE class:
    /// `class name -> µs at nominal frequency` (a Table-1 row).
    pub exec_us: BTreeMap<String, f64>,
    /// Indices of predecessor tasks within the same [`AppGraph`].
    pub preds: Vec<usize>,
    /// Output payload size (bytes) shipped to each successor over the NoC.
    pub out_bytes: u64,
}

impl TaskSpec {
    /// Minimum execution time over all supporting classes (µs).
    pub fn min_exec_us(&self) -> f64 {
        self.exec_us
            .values()
            .copied()
            .fold(f64::INFINITY, f64::min)
    }

    /// Mean execution time over supporting classes (HEFT's rank metric).
    pub fn mean_exec_us(&self) -> f64 {
        if self.exec_us.is_empty() {
            return 0.0;
        }
        self.exec_us.values().sum::<f64>() / self.exec_us.len() as f64
    }
}

/// A validated application DAG.
#[derive(Debug, Clone)]
pub struct AppGraph {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
    /// Successor lists (derived from `preds` at construction).
    succs: Vec<Vec<usize>>,
    /// A topological order of task indices.
    topo: Vec<usize>,
}

impl AppGraph {
    /// Build and validate: predecessor indices in range, graph acyclic,
    /// every task runnable somewhere, names unique.
    pub fn new(name: impl Into<String>, tasks: Vec<TaskSpec>) -> Result<Self> {
        let name = name.into();
        let n = tasks.len();
        if n == 0 {
            return Err(Error::App(format!("app '{name}' has no tasks")));
        }
        let mut names = std::collections::BTreeSet::new();
        for (i, t) in tasks.iter().enumerate() {
            if !names.insert(t.name.clone()) {
                return Err(Error::App(format!(
                    "app '{name}': duplicate task name '{}'",
                    t.name
                )));
            }
            if t.exec_us.is_empty() {
                return Err(Error::App(format!(
                    "app '{name}': task '{}' supports no PE class",
                    t.name
                )));
            }
            for (cls, &us) in t.exec_us.iter() {
                if !(us > 0.0) || !us.is_finite() {
                    return Err(Error::App(format!(
                        "app '{name}': task '{}' class '{cls}' latency {us}",
                        t.name
                    )));
                }
            }
            for &p in &t.preds {
                if p >= n {
                    return Err(Error::App(format!(
                        "app '{name}': task {i} pred {p} out of range"
                    )));
                }
                if p == i {
                    return Err(Error::App(format!(
                        "app '{name}': task {i} depends on itself"
                    )));
                }
            }
        }

        // Kahn's algorithm: topological order + cycle detection.
        let mut succs = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, t) in tasks.iter().enumerate() {
            indeg[i] = t.preds.len();
            for &p in &t.preds {
                succs[p].push(i);
            }
        }
        let mut queue: Vec<usize> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut topo = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            topo.push(u);
            for &v in &succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if topo.len() != n {
            return Err(Error::App(format!("app '{name}' contains a cycle")));
        }
        Ok(AppGraph { name, tasks, succs, topo })
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn succs(&self, task: usize) -> &[usize] {
        &self.succs[task]
    }

    pub fn topo_order(&self) -> &[usize] {
        &self.topo
    }

    /// Tasks with no predecessors (job entry points).
    pub fn sources(&self) -> Vec<usize> {
        (0..self.len())
            .filter(|&i| self.tasks[i].preds.is_empty())
            .collect()
    }

    /// Tasks with no successors (job completion requires all of them).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.succs[i].is_empty()).collect()
    }

    /// Length of the critical path assuming every task runs at its
    /// minimum latency and communication is free: the best possible job
    /// execution time on an unloaded, infinitely wide platform.
    pub fn critical_path_us(&self) -> f64 {
        let mut dist = vec![0.0f64; self.len()];
        for &u in &self.topo {
            let t = self.tasks[u].min_exec_us();
            let start = self.tasks[u]
                .preds
                .iter()
                .map(|&p| dist[p])
                .fold(0.0, f64::max);
            dist[u] = start + t;
        }
        dist.iter().copied().fold(0.0, f64::max)
    }

    /// Total work (sum of min latencies), a lower bound on busy time.
    pub fn total_work_us(&self) -> f64 {
        self.tasks.iter().map(TaskSpec::min_exec_us).sum()
    }

    /// Maximum number of tasks that can be in flight simultaneously
    /// (antichain width upper bound via level sizes).
    pub fn max_width(&self) -> usize {
        let mut level = vec![0usize; self.len()];
        let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
        for &u in &self.topo {
            let l = self.tasks[u]
                .preds
                .iter()
                .map(|&p| level[p] + 1)
                .max()
                .unwrap_or(0);
            level[u] = l;
            *counts.entry(l).or_insert(0) += 1;
        }
        counts.values().copied().max().unwrap_or(0)
    }

    // ---- JSON (config-driven custom applications) -----------------------

    pub fn to_json(&self) -> Json {
        let mut tasks = Vec::new();
        for t in &self.tasks {
            let mut jt = Json::obj();
            jt.set("name", Json::Str(t.name.clone()));
            let mut exec = Json::obj();
            for (k, v) in &t.exec_us {
                exec.set(k, Json::Num(*v));
            }
            jt.set("exec_us", exec);
            jt.set(
                "preds",
                Json::Arr(
                    t.preds.iter().map(|&p| Json::Num(p as f64)).collect(),
                ),
            );
            jt.set("out_bytes", Json::Num(t.out_bytes as f64));
            tasks.push(jt);
        }
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("tasks", Json::Arr(tasks));
        j
    }

    pub fn from_json(j: &Json) -> Result<AppGraph> {
        let name = j.req_str("name")?.to_string();
        let mut tasks = Vec::new();
        for jt in j.req_arr("tasks")? {
            let tname = jt.req_str("name")?.to_string();
            let mut exec_us = BTreeMap::new();
            let exec = jt
                .get("exec_us")
                .and_then(Json::as_obj)
                .ok_or_else(|| Error::Json("missing exec_us".into()))?;
            for (k, v) in exec {
                exec_us.insert(
                    k.clone(),
                    v.as_f64().ok_or_else(|| {
                        Error::Json(format!("bad latency for '{k}'"))
                    })?,
                );
            }
            let preds = jt
                .req_arr("preds")?
                .iter()
                .map(|p| {
                    p.as_usize().ok_or_else(|| {
                        Error::Json("bad pred index".into())
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let out_bytes = jt
                .get("out_bytes")
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64;
            tasks.push(TaskSpec { name: tname, exec_us, preds, out_bytes });
        }
        AppGraph::new(name, tasks)
    }
}

/// Convenience builder used by the suite and by tests.
pub struct DagBuilder {
    name: String,
    tasks: Vec<TaskSpec>,
}

impl DagBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        DagBuilder { name: name.into(), tasks: Vec::new() }
    }

    /// Add a task; `exec` is `[(class, µs)]`; returns its index.
    pub fn task(
        &mut self,
        name: impl Into<String>,
        exec: &[(&str, f64)],
        preds: &[usize],
        out_bytes: u64,
    ) -> usize {
        let id = self.tasks.len();
        self.tasks.push(TaskSpec {
            name: name.into(),
            exec_us: exec
                .iter()
                .map(|&(c, us)| (c.to_string(), us))
                .collect(),
            preds: preds.to_vec(),
            out_bytes,
        });
        id
    }

    pub fn build(self) -> Result<AppGraph> {
        AppGraph::new(self.name, self.tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> AppGraph {
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", &[("A15", 10.0)], &[], 64);
        let l = b.task("l", &[("A15", 5.0), ("A7", 12.0)], &[a], 64);
        let r = b.task("r", &[("A15", 7.0)], &[a], 64);
        let _s = b.task("s", &[("A15", 1.0)], &[l, r], 0);
        b.build().unwrap()
    }

    #[test]
    fn topo_respects_edges() {
        let g = diamond();
        let pos: BTreeMap<usize, usize> = g
            .topo_order()
            .iter()
            .enumerate()
            .map(|(i, &t)| (t, i))
            .collect();
        for (i, t) in g.tasks.iter().enumerate() {
            for &p in &t.preds {
                assert!(pos[&p] < pos[&i]);
            }
        }
    }

    #[test]
    fn critical_path_of_diamond() {
        let g = diamond();
        // a(10) -> r(7) -> s(1) = 18 (left branch is 5).
        assert!((g.critical_path_us() - 18.0).abs() < 1e-9);
        assert!((g.total_work_us() - 23.0).abs() < 1e-9);
        assert_eq!(g.max_width(), 2);
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn rejects_cycle() {
        let r = AppGraph::new(
            "cyc",
            vec![
                TaskSpec {
                    name: "a".into(),
                    exec_us: [("A15".to_string(), 1.0)].into(),
                    preds: vec![1],
                    out_bytes: 0,
                },
                TaskSpec {
                    name: "b".into(),
                    exec_us: [("A15".to_string(), 1.0)].into(),
                    preds: vec![0],
                    out_bytes: 0,
                },
            ],
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_self_loop_and_bad_index() {
        let mk = |preds: Vec<usize>| {
            AppGraph::new(
                "bad",
                vec![TaskSpec {
                    name: "a".into(),
                    exec_us: [("A15".to_string(), 1.0)].into(),
                    preds,
                    out_bytes: 0,
                }],
            )
        };
        assert!(mk(vec![0]).is_err());
        assert!(mk(vec![5]).is_err());
    }

    #[test]
    fn rejects_unsupported_task() {
        let r = AppGraph::new(
            "none",
            vec![TaskSpec {
                name: "a".into(),
                exec_us: BTreeMap::new(),
                preds: vec![],
                out_bytes: 0,
            }],
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let t = TaskSpec {
            name: "a".into(),
            exec_us: [("A15".to_string(), 1.0)].into(),
            preds: vec![],
            out_bytes: 0,
        };
        assert!(AppGraph::new("dup", vec![t.clone(), t]).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let g = diamond();
        let j = g.to_json();
        let g2 = AppGraph::from_json(&j).unwrap();
        assert_eq!(g2.name, g.name);
        assert_eq!(g2.len(), g.len());
        for (a, b) in g.tasks.iter().zip(&g2.tasks) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.preds, b.preds);
            assert_eq!(a.exec_us, b.exec_us);
        }
    }

    #[test]
    fn min_and_mean_exec() {
        let g = diamond();
        assert_eq!(g.tasks[1].min_exec_us(), 5.0);
        assert!((g.tasks[1].mean_exec_us() - 8.5).abs() < 1e-12);
    }
}
