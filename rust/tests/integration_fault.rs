//! Fault-isolated campaign execution, end to end: panic quarantine
//! through the pooled sweep primitive, the deterministic step-budget
//! watchdog, never-cache semantics for failed points, and crash-safe
//! store recovery (torn index tail + `fsck`).
//!
//! Every armed fault uses a label no other test sweeps (rates 3.375 /
//! 4.625) — the faultpoint registry is process-global and cargo runs
//! tests in parallel threads.

use std::sync::Arc;

use ds3r::app::suite;
use ds3r::config::SimConfig;
use ds3r::coordinator::{self, FailPolicy};
use ds3r::faultpoint::{sites, Armed, Fault};
use ds3r::platform::Platform;
use ds3r::sim::Simulation;
use ds3r::store::{ExperimentStore, Manifest, StoreCtx};
use ds3r::telemetry::{Counters, MemSink, Telemetry};
use ds3r::util::json::Json;

fn small_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 25;
    cfg.warmup_jobs = 3;
    cfg.seed = 42;
    cfg
}

fn small_apps() -> Vec<ds3r::app::AppGraph> {
    vec![suite::wifi_tx(suite::WifiParams { symbols: 2 })]
}

#[test]
fn injected_panic_quarantines_identically_across_thread_counts() {
    let platform = Platform::table2_soc();
    let apps = small_apps();
    let cfg = small_cfg();
    let points =
        coordinator::fig3_points(&["met", "etf"], &[3.375], cfg.seed);
    let _fault =
        Armed::new(sites::SWEEP_POINT, "met@3.375", Fault::Panic);

    let run = |threads: usize| {
        let mem = Arc::new(MemSink::new());
        let tel = Telemetry::new(mem.clone());
        let (res, _counters, failures) =
            coordinator::run_sweep_quarantined(
                &platform,
                &apps,
                &cfg,
                &points,
                threads,
                &tel,
                None,
                FailPolicy::Quarantine { max_failures: None },
            )
            .unwrap();
        let rendered: Vec<String> =
            res.iter().map(|r| r.to_json().to_string()).collect();
        (rendered, failures, mem.dump())
    };

    let (res1, fail1, stream1) = run(1);
    let (res8, fail8, stream8) = run(8);

    // Healthy results survive, in input order, byte-identical for any
    // thread count; the panicked point is quarantined in both runs.
    assert_eq!(res1.len(), 1, "etf survives, met is quarantined");
    assert_eq!(res1, res8);
    assert_eq!(fail1, fail8);
    assert_eq!(fail1.quarantined(), 1);
    assert_eq!(fail1.failed[0].label, "met@3.375");
    assert_eq!(fail1.failed[0].kind, "panic");
    assert!(
        fail1.failed[0].detail.contains("injected panic"),
        "{}",
        fail1.failed[0].detail
    );

    // The default telemetry stream — including the point_failed event
    // — is byte-identical between 1 and 8 worker threads.
    assert_eq!(stream1, stream8);
    assert!(stream1.contains("point_failed"), "{stream1}");
    assert!(stream1.contains("met@3.375"), "{stream1}");
}

#[test]
fn watchdog_step_budget_trips_bit_reproducibly() {
    let platform = Platform::table2_soc();
    let apps = small_apps();
    let mut cfg = small_cfg();
    cfg.max_jobs = 40;
    cfg.step_budget = 100;

    let r1 = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    let r2 = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    assert!(r1.timed_out, "40 jobs cannot finish in 100 loop steps");
    // The counter is event-loop iterations, never wall clock: it
    // trips at exactly the budget, on every host, every run.
    assert_eq!(r1.watchdog_steps, 100);
    assert_eq!(r1.to_json().to_string(), r2.to_json().to_string());
    assert!(r1.summary().contains("WATCHDOG"), "{}", r1.summary());

    // Under abort policy a tripped watchdog fails the campaign...
    let points =
        coordinator::fig3_points(&["met", "etf"], &[1.5], cfg.seed);
    let tel = Telemetry::disabled();
    let err = coordinator::run_sweep_quarantined(
        &platform,
        &apps,
        &cfg,
        &points,
        2,
        &tel,
        None,
        FailPolicy::Abort,
    )
    .unwrap_err();
    assert!(err.to_string().contains("step budget"), "{err}");

    // ...under quarantine both over-budget points are dropped with a
    // deterministic "timeout" verdict.
    let (res, _counters, failures) = coordinator::run_sweep_quarantined(
        &platform,
        &apps,
        &cfg,
        &points,
        2,
        &tel,
        None,
        FailPolicy::Quarantine { max_failures: None },
    )
    .unwrap();
    assert!(res.is_empty());
    assert_eq!(failures.quarantined(), 2);
    assert!(failures.failed.iter().all(|f| f.kind == "timeout"));
}

#[test]
fn failed_points_are_never_cached_and_heal_after_disarm() {
    let platform = Platform::table2_soc();
    let apps = small_apps();
    let cfg = small_cfg();
    let points =
        coordinator::fig3_points(&["met", "ilp"], &[4.625], cfg.seed);
    let dir = std::env::temp_dir().join("ds3r_it_fault_store");
    let _ = std::fs::remove_dir_all(&dir);

    let run = |policy: FailPolicy| {
        // A fresh handle per campaign: session hit/miss counters and
        // the on-disk cache behave exactly like separate processes.
        let store = ExperimentStore::open(&dir).unwrap();
        let ctx = StoreCtx {
            store: store.clone(),
            workload_digest: "wd-it-fault".into(),
        };
        let tel = Telemetry::disabled();
        let (res, _counters, failures) =
            coordinator::run_sweep_quarantined(
                &platform,
                &apps,
                &cfg,
                &points,
                2,
                &tel,
                Some(&ctx),
                policy,
            )
            .unwrap();
        let rendered: Vec<String> =
            res.iter().map(|r| r.to_json().to_string()).collect();
        (rendered, failures, store.session_hits())
    };
    let quarantine = FailPolicy::Quarantine { max_failures: None };

    let fault =
        Armed::new(sites::SWEEP_POINT, "ilp@4.625", Fault::Panic);
    let (cold, fail_cold, _) = run(quarantine);
    assert_eq!(cold.len(), 1);
    assert_eq!(fail_cold.quarantined(), 1);
    assert_eq!(fail_cold.failed[0].label, "ilp@4.625");

    // Warm rerun, fault still armed: the healthy point is served from
    // the cache, the failed one was never written and fails again.
    let (warm, fail_warm, hits) = run(quarantine);
    assert_eq!(hits, 1, "only the healthy point was cached");
    assert_eq!(warm, cold);
    assert_eq!(fail_warm, fail_cold);

    // Disarmed, the campaign heals: the quarantined point simulates
    // now and the healthy one still matches the cold run byte for
    // byte.
    drop(fault);
    let (healed, fail_healed, hits) = run(quarantine);
    assert_eq!(hits, 1);
    assert!(fail_healed.is_clean());
    assert_eq!(healed.len(), 2);
    assert!(healed.contains(&cold[0]));
}

#[test]
fn store_open_salvages_torn_index_and_fsck_recovers_corruption() {
    let dir = std::env::temp_dir().join("ds3r_it_fault_salvage");
    let _ = std::fs::remove_dir_all(&dir);
    let store = ExperimentStore::open(&dir).unwrap();
    let m1 = Manifest {
        cmd: "sweep".into(),
        config_hash: "cafecafecafecafe".into(),
        workload_digest: "wdwdwdwdwdwdwdwd".into(),
        seed: 7,
        scheduler: "etf".into(),
        git: None,
        counters: Counters::new(),
        point_keys: Vec::new(),
        result: Json::obj(),
    };
    let k1 = store.put_manifest(&m1).unwrap();
    drop(store);

    // Crash mid-append: a truncated JSON fragment ends the index.
    let idx = dir.join("index.jsonl");
    let mut text = std::fs::read_to_string(&idx).unwrap();
    text.push_str("{\"key\":\"zzz\",\"cmd\":\"swe");
    std::fs::write(&idx, &text).unwrap();
    // And a corrupt manifest file next to the intact one.
    std::fs::write(
        dir.join("manifests").join("feedfeedfeedfeed.json"),
        "{ torn",
    )
    .unwrap();

    // Open salvages the torn tail; the intact manifest is intact.
    let store = ExperimentStore::open(&dir).unwrap();
    let manifests = store.manifests();
    assert_eq!(manifests.len(), 1);
    assert_eq!(manifests[0].key(), k1);

    // fsck quarantines the unparseable manifest (preserved, not
    // deleted) and reports the salvaged tail; verify passes on what
    // remains, and a second fsck is clean.
    let s = store.fsck().unwrap();
    assert!(s.index_tail_salvaged);
    assert_eq!(s.manifests_kept, 1);
    assert_eq!(s.manifests_quarantined, 1);
    assert!(dir
        .join("quarantine")
        .join("feedfeedfeedfeed.json")
        .exists());
    assert!(store.verify().unwrap().ok());

    let store = ExperimentStore::open(&dir).unwrap();
    assert!(store.fsck().unwrap().clean());
    let _ = std::fs::remove_dir_all(&dir);
}
