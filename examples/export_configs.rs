//! Export the built-in Table-2 platform and a Figure-3 experiment point
//! as JSON config files (written to `configs/`): the starting point for
//! defining your own DSSoC candidates without recompiling.
//!
//! ```sh
//! cargo run --release --example export_configs
//! ds3r run --platform configs/table2_platform.json \
//!          --config configs/fig3_point.json
//! ```

fn main() {
    std::fs::create_dir_all("configs").expect("mkdir configs");

    let p = ds3r::platform::Platform::table2_soc();
    std::fs::write(
        "configs/table2_platform.json",
        p.to_json().to_string_pretty(),
    )
    .expect("write platform");

    let mut cfg = ds3r::config::SimConfig::default();
    cfg.scheduler = "etf".into();
    cfg.injection_rate_per_ms = 5.0;
    cfg.max_jobs = 1000;
    cfg.warmup_jobs = 100;
    cfg.dtpm.governor = "ondemand".into();
    cfg.save(std::path::Path::new("configs/fig3_point.json"))
        .expect("write experiment config");

    println!(
        "wrote configs/table2_platform.json and configs/fig3_point.json"
    );
}
