//! Table 2 bench: regenerates the scheduling-case-study SoC
//! configuration and measures platform-model operations (construction,
//! validation, NoC precomputation, PE snapshotting).
//!
//! Run: `cargo bench --bench table2_platform`

mod bench_util;

use ds3r::noc::NocModel;
use ds3r::platform::Platform;

fn main() {
    println!("=== Table 2 regeneration ===\n");
    println!("{}", ds3r::cli::reproduce_table2());

    println!("--- platform-model microbenchmarks ---");
    bench_util::bench("Platform::table2_soc (build + validate)", 50_000, || {
        std::hint::black_box(Platform::table2_soc());
    });

    let p = Platform::table2_soc();
    bench_util::bench("NocModel::new (hop-table precompute)", 100_000, || {
        std::hint::black_box(NocModel::new(&p, false));
    });

    let noc = NocModel::new(&p, false);
    let mut acc = 0.0;
    bench_util::bench("NoC transfer latency query", 1_000_000, || {
        acc += noc.transfer_us(0, 9, 512);
    });
    std::hint::black_box(acc);

    bench_util::bench("inventory() (Table-2 rows)", 200_000, || {
        std::hint::black_box(p.inventory());
    });

    let zcu = ds3r::platform::presets::zcu102_soc();
    println!(
        "\nvalidation platform: {} with {} PEs ({} FFT engines)",
        zcu.name,
        zcu.n_pes(),
        zcu.inventory()
            .iter()
            .find(|(n, _, _)| n == "ACC_FFT")
            .map(|x| x.2)
            .unwrap_or(0)
    );

    // Cross-check against the paper's Table 2 numbers, loudly.
    let inv: std::collections::BTreeMap<String, usize> = p
        .inventory()
        .into_iter()
        .map(|(n, _, c)| (n, c))
        .collect();
    let ok = inv["A15"] == 4
        && inv["A7"] == 4
        && inv["ACC_SCR"] == 2
        && inv["ACC_FFT"] == 4
        && p.n_pes() == 14;
    println!(
        "Table 2 values vs paper: {}",
        if ok { "EXACT MATCH" } else { "MISMATCH" }
    );
}
