//! Structured telemetry: typed events, deterministic counters, and
//! pluggable sinks for long-running campaigns.
//!
//! Real DS3R campaigns — sweeps, DSE generations, IL training — run
//! for hours; this module is the one substrate behind their progress
//! reporting, their machine-readable event streams, and the run
//! manifests the future experiment store (ROADMAP item 2) and `serve`
//! daemon (item 3) consume.
//!
//! ## Design rules
//!
//! * **Zero-cost when disabled.** Every emission point goes through
//!   [`Telemetry::emit`] (or the global [`emit_global`]), which takes a
//!   *closure* building the event — with no sink installed the check is
//!   a single branch (one relaxed atomic load on the global path) and
//!   the event is never constructed.  `perf_hotpath` guards the
//!   disabled cost at <1% events/s.
//! * **Deterministic by default.** Events are split into a
//!   *deterministic* set (run lifecycle, counters, per-phase stats,
//!   DSE generations, learn rounds, diagnostics) and a *wall-clock*
//!   set (progress rates, ETAs, timing spans, bench records).  A
//!   [`JsonlSink`] without [`JsonlSink::with_timing`] records only the
//!   deterministic set and omits every wall-clock field, so a
//!   fixed-seed campaign emits a **byte-identical** JSONL stream
//!   regardless of thread count — asserted by
//!   `rust/tests/integration_telemetry.rs`.
//! * **Library code emits events; only the CLI renders text.**  Sinks
//!   here write machine-readable JSONL; the human renderings (progress
//!   lines, diagnostic text) live in `cli.rs`/`main.rs`, the only
//!   modules exempt from the CI `print_stdout`/`print_stderr` clippy
//!   gate.
//!
//! ## Counters
//!
//! [`Counters`] is a deterministic (sorted-key) registry of named
//! `u64` totals.  Pooled grids
//! ([`crate::coordinator::parallel_map_pooled_counted`]) give each
//! worker a per-item registry and fold the per-item deltas **in input
//! order**, so a 1-thread and an 8-thread sweep aggregate to identical
//! counters — and identical `run_finished` bytes.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::stats::{DseGenStats, PhaseStats, SimReport};
use crate::util::json::Json;
use crate::Result;

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One structured telemetry event.  `kind()` names it in the JSONL
/// stream; `is_deterministic()` decides whether a non-timing sink
/// records it.
#[derive(Debug, Clone)]
pub enum Event {
    /// A top-level invocation began (one per CLI command / campaign).
    RunStarted {
        /// Subcommand or campaign label (`run`, `sweep`, `dse-run`...).
        cmd: String,
        /// FNV-1a hash of the canonical config JSON (hex).
        config_hash: String,
        /// FNV-1a digest over every workload *input* — app DAGs, trace
        /// files, XLA artifacts, scenario/fuzz JSON, the IL policy
        /// ([`crate::store::workload_digest`]).  Together with
        /// `config_hash` this makes store keys content-addressed:
        /// editing a trace file changes the key even though the config
        /// JSON (which records only the path) does not.
        workload_digest: String,
        seed: u64,
        scheduler: String,
        /// `git describe --always --dirty` of the working tree, when
        /// available (environment metadata for run manifests).
        git: Option<String>,
    },
    /// The invocation finished; carries the aggregated deterministic
    /// counters and (timing sinks only) the wall-clock cost.
    RunFinished {
        cmd: String,
        counters: Counters,
        /// Wall-clock seconds for the whole invocation (wall-clock
        /// field: omitted by non-timing sinks).
        wall_s: f64,
    },
    /// Live progress of a pooled grid (wall-clock event: rates and
    /// ETAs are never deterministic).
    SweepProgress {
        completed: usize,
        total: usize,
        sims_per_s: f64,
        eta_s: f64,
    },
    /// One scenario phase condensed from a finished run (deterministic;
    /// emitted in input order after the grid completes).
    ScenarioPhase { scenario: String, phase: PhaseStats },
    /// One DSE generation summary (deterministic — `DseGenStats`
    /// carries no wall-clock fields).
    DseGeneration { stats: DseGenStats },
    /// One imitation-learning round (deterministic).
    LearnRound {
        round: usize,
        /// Demonstrations aggregated so far (all rounds).
        samples: usize,
        /// Deployment agreement with the oracle this round (absent for
        /// the behavioural-cloning round 0).
        agreement: Option<f64>,
    },
    /// One benchmark measurement (wall-clock event — benches install a
    /// timing sink).
    BenchRecord {
        bench: String,
        name: String,
        value: f64,
        unit: String,
    },
    /// One scheduler × generated-scenario tournament cell
    /// (deterministic; emitted in canonical cell order after the
    /// pooled grid completes, like [`Event::ScenarioPhase`]).
    FuzzCase {
        scheduler: String,
        case: usize,
        scenario: String,
        max_latency_us: f64,
        violations: usize,
    },
    /// Closing summary of one fuzz tournament (deterministic).
    TournamentSummary {
        cases: usize,
        schedulers: usize,
        cells: usize,
        violations: usize,
        /// Top-ranked scheduler (empty when no standings).
        best: String,
    },
    /// One grid point quarantined under a degraded-mode fail policy
    /// (deterministic: emitted post-collection in input order, and the
    /// verdict — panic message, watchdog step count, error text — is a
    /// function of (config, seed), never of wall clock or thread
    /// interleaving).
    PointFailed {
        /// Campaign kind (`sweep`, `scenario`, `fuzz`, `dse`).
        what: String,
        /// Point label (`"{scheduler}@{rate}"`, scenario name, ...).
        label: String,
        /// Failure class: `panic`, `timeout` or `error`.
        kind: String,
        detail: String,
    },
    /// The experiment store finalized a manifest for this invocation
    /// (deterministic: the key hashes only config/workload/seed
    /// identity, so warm and cold reruns emit identical bytes).
    ManifestWritten { cmd: String, key: String },
    /// A library diagnostic that previously went to `eprintln!`
    /// (deterministic: it reflects simulated behaviour, not wall time).
    Diagnostic { component: String, message: String },
    /// A named wall-clock span (wall-clock event).
    Span { name: String, wall_ns: u64 },
    /// Cache economics of the experiment store for one invocation.
    /// Environment-dependent (hit/miss rates reflect prior store
    /// state, not (config, seed)), so it is excluded from
    /// deterministic streams — cold and warm reruns must stay
    /// byte-identical.  The CLI renders it to stderr regardless.
    StoreStats { cmd: String, hits: u64, misses: u64 },
    /// Wall-clock self-profile of one simulation run: where the
    /// runtime went, bucketed by kernel stage (wall-clock event).
    Profile {
        cmd: String,
        build_wall_ns: u64,
        sched_wall_ns: u64,
        thermal_wall_ns: u64,
        jobgen_wall_ns: u64,
        loop_wall_ns: u64,
    },
}

impl Event {
    /// Stream name of this event kind (the `"event"` JSONL field).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::RunStarted { .. } => "run_started",
            Event::RunFinished { .. } => "run_finished",
            Event::SweepProgress { .. } => "sweep_progress",
            Event::ScenarioPhase { .. } => "scenario_phase",
            Event::DseGeneration { .. } => "dse_generation",
            Event::LearnRound { .. } => "learn_round",
            Event::BenchRecord { .. } => "bench_record",
            Event::FuzzCase { .. } => "fuzz_case",
            Event::TournamentSummary { .. } => "tournament_summary",
            Event::PointFailed { .. } => "point_failed",
            Event::ManifestWritten { .. } => "manifest_written",
            Event::Diagnostic { .. } => "diagnostic",
            Event::Span { .. } => "span",
            Event::StoreStats { .. } => "store_stats",
            Event::Profile { .. } => "profile",
        }
    }

    /// Whether this event is a deterministic function of (config,
    /// seed) — i.e. safe to include in a byte-identical golden stream.
    pub fn is_deterministic(&self) -> bool {
        !matches!(
            self,
            Event::SweepProgress { .. }
                | Event::BenchRecord { .. }
                | Event::Span { .. }
                | Event::StoreStats { .. }
                | Event::Profile { .. }
        )
    }

    /// Serialize for the JSONL stream.  With `timing == false` every
    /// wall-clock field is omitted, keeping the line deterministic.
    pub fn to_json(&self, timing: bool) -> Json {
        let mut j = Json::obj();
        j.set("event", Json::Str(self.kind().into()));
        match self {
            Event::RunStarted {
                cmd,
                config_hash,
                workload_digest,
                seed,
                scheduler,
                git,
            } => {
                j.set("cmd", Json::Str(cmd.clone()))
                    .set("config_hash", Json::Str(config_hash.clone()))
                    .set(
                        "workload_digest",
                        Json::Str(workload_digest.clone()),
                    )
                    .set("seed", crate::util::json::u64_to_json(*seed))
                    .set("scheduler", Json::Str(scheduler.clone()))
                    .set(
                        "git",
                        match git {
                            Some(g) => Json::Str(g.clone()),
                            None => Json::Null,
                        },
                    );
            }
            Event::RunFinished { cmd, counters, wall_s } => {
                j.set("cmd", Json::Str(cmd.clone()))
                    .set("counters", counters.to_json());
                if timing {
                    j.set("wall_s", Json::Num(*wall_s));
                }
            }
            Event::SweepProgress { completed, total, sims_per_s, eta_s } => {
                j.set("completed", Json::Num(*completed as f64))
                    .set("total", Json::Num(*total as f64))
                    .set("sims_per_s", Json::Num(*sims_per_s))
                    .set("eta_s", Json::Num(*eta_s));
            }
            Event::ScenarioPhase { scenario, phase } => {
                j.set("scenario", Json::Str(scenario.clone()))
                    .set("label", Json::Str(phase.label.clone()))
                    .set("start_us", Json::Num(phase.start_us))
                    .set("end_us", Json::Num(phase.end_us))
                    .set(
                        "jobs_completed",
                        Json::Num(phase.jobs_completed as f64),
                    )
                    .set("avg_latency_us", Json::Num(phase.avg_latency_us))
                    .set("p95_latency_us", Json::Num(phase.p95_latency_us))
                    .set("energy_j", Json::Num(phase.energy_j))
                    .set("avg_power_w", Json::Num(phase.avg_power_w))
                    .set("peak_temp_c", Json::Num(phase.peak_temp_c));
            }
            Event::DseGeneration { stats } => {
                if let Json::Obj(fields) = stats.to_json() {
                    for (k, v) in fields {
                        j.set(&k, v);
                    }
                }
            }
            Event::LearnRound { round, samples, agreement } => {
                j.set("round", Json::Num(*round as f64))
                    .set("samples", Json::Num(*samples as f64))
                    .set(
                        "agreement",
                        match agreement {
                            Some(a) => Json::Num(*a),
                            None => Json::Null,
                        },
                    );
            }
            Event::BenchRecord { bench, name, value, unit } => {
                j.set("bench", Json::Str(bench.clone()))
                    .set("name", Json::Str(name.clone()))
                    .set("value", Json::Num(*value))
                    .set("unit", Json::Str(unit.clone()));
            }
            Event::FuzzCase {
                scheduler,
                case,
                scenario,
                max_latency_us,
                violations,
            } => {
                j.set("scheduler", Json::Str(scheduler.clone()))
                    .set("case", Json::Num(*case as f64))
                    .set("scenario", Json::Str(scenario.clone()))
                    .set("max_latency_us", Json::Num(*max_latency_us))
                    .set("violations", Json::Num(*violations as f64));
            }
            Event::TournamentSummary {
                cases,
                schedulers,
                cells,
                violations,
                best,
            } => {
                j.set("cases", Json::Num(*cases as f64))
                    .set("schedulers", Json::Num(*schedulers as f64))
                    .set("cells", Json::Num(*cells as f64))
                    .set("violations", Json::Num(*violations as f64))
                    .set("best", Json::Str(best.clone()));
            }
            Event::PointFailed { what, label, kind, detail } => {
                j.set("what", Json::Str(what.clone()))
                    .set("label", Json::Str(label.clone()))
                    .set("kind", Json::Str(kind.clone()))
                    .set("detail", Json::Str(detail.clone()));
            }
            Event::ManifestWritten { cmd, key } => {
                j.set("cmd", Json::Str(cmd.clone()))
                    .set("key", Json::Str(key.clone()));
            }
            Event::Diagnostic { component, message } => {
                j.set("component", Json::Str(component.clone()))
                    .set("message", Json::Str(message.clone()));
            }
            Event::Span { name, wall_ns } => {
                j.set("name", Json::Str(name.clone()))
                    .set("wall_ns", crate::util::json::u64_to_json(*wall_ns));
            }
            Event::StoreStats { cmd, hits, misses } => {
                j.set("cmd", Json::Str(cmd.clone()))
                    .set("hits", crate::util::json::u64_to_json(*hits))
                    .set("misses", crate::util::json::u64_to_json(*misses));
            }
            Event::Profile {
                cmd,
                build_wall_ns,
                sched_wall_ns,
                thermal_wall_ns,
                jobgen_wall_ns,
                loop_wall_ns,
            } => {
                let u = crate::util::json::u64_to_json;
                j.set("cmd", Json::Str(cmd.clone()))
                    .set("build_wall_ns", u(*build_wall_ns))
                    .set("sched_wall_ns", u(*sched_wall_ns))
                    .set("thermal_wall_ns", u(*thermal_wall_ns))
                    .set("jobgen_wall_ns", u(*jobgen_wall_ns))
                    .set("loop_wall_ns", u(*loop_wall_ns));
            }
        }
        j
    }
}

// ---------------------------------------------------------------------------
// Counters
// ---------------------------------------------------------------------------

/// A registry of named monotone `u64` totals with deterministic
/// (sorted-key) iteration and serialization.  Merging is plain
/// addition, so any fold order yields the same totals — pooled grids
/// still fold per-item deltas in input order (the stronger contract,
/// robust to future non-commutative merges).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    map: BTreeMap<String, u64>,
}

impl Counters {
    pub fn new() -> Counters {
        Counters::default()
    }

    /// Add `n` to the named counter (creating it at 0).
    pub fn add(&mut self, key: &str, n: u64) {
        if let Some(v) = self.map.get_mut(key) {
            *v += n;
        } else {
            self.map.insert(key.to_string(), n);
        }
    }

    pub fn get(&self, key: &str) -> u64 {
        self.map.get(key).copied().unwrap_or(0)
    }

    /// Fold another registry into this one (addition per key).
    pub fn merge(&mut self, other: &Counters) {
        for (k, &v) in &other.map {
            self.add(k, v);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.map.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// The deterministic kernel counters of one finished run — the
    /// per-point delta pooled grids aggregate.
    pub fn from_report(r: &SimReport) -> Counters {
        let mut c = Counters::new();
        c.add("runs", 1);
        c.add("injected_jobs", r.injected_jobs as u64);
        c.add("completed_jobs", r.completed_jobs as u64);
        c.add("events_processed", r.events_processed);
        c.add("sched_invocations", r.sched_invocations);
        c.add("tasks_executed", r.tasks_executed);
        c.add("sched_decisions", r.sched_decisions);
        c.add("sched_fallbacks", r.sched_fallbacks);
        c.add("deferred_epochs", r.deferred_epochs);
        c.add("thermal_flushes", r.thermal_flushes);
        c.add("scenario_events", r.scenario_events);
        c.add("device_calls", r.device_calls);
        c.add("throttle_engagements", r.throttle_engagements);
        c
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (k, &v) in &self.map {
            j.set(k, Json::Num(v as f64));
        }
        j
    }

    /// Inverse of [`Counters::to_json`] — the experiment store
    /// round-trips per-point and per-campaign counter registries
    /// through manifest files.
    pub fn from_json(j: &Json) -> Result<Counters> {
        let obj = j.as_obj().ok_or_else(|| {
            crate::Error::Json("counters: expected object".into())
        })?;
        let mut c = Counters::new();
        for (k, v) in obj {
            let n = crate::util::json::u64_from_json(v).ok_or_else(
                || {
                    crate::Error::Json(format!(
                        "counters: non-integer value at key '{k}'"
                    ))
                },
            )?;
            c.add(k, n);
        }
        Ok(c)
    }
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// Destination for telemetry events.  Implementations must be
/// thread-safe: pooled grid workers emit concurrently.
pub trait Sink: Send + Sync {
    fn emit(&self, ev: &Event);
    fn flush(&self) {}
}

/// JSON-lines emitter over any writer (file, stderr, memory buffer).
/// Without timing mode it records only deterministic events and omits
/// wall-clock fields — the golden-stream configuration.
pub struct JsonlSink {
    out: Mutex<Box<dyn Write + Send>>,
    timing: bool,
}

impl JsonlSink {
    pub fn from_writer(w: Box<dyn Write + Send>) -> JsonlSink {
        JsonlSink { out: Mutex::new(w), timing: false }
    }

    /// Create (truncate) a JSONL file sink.
    pub fn create(path: &std::path::Path) -> Result<JsonlSink> {
        Ok(JsonlSink::from_writer(Box::new(std::fs::File::create(
            path,
        )?)))
    }

    /// Stream to stderr (the `--telemetry -` configuration).
    pub fn stderr() -> JsonlSink {
        JsonlSink::from_writer(Box::new(std::io::stderr()))
    }

    /// Include wall-clock events/fields (progress rates, spans, bench
    /// records).  The stream is no longer byte-deterministic.
    pub fn with_timing(mut self, timing: bool) -> JsonlSink {
        self.timing = timing;
        self
    }
}

impl Sink for JsonlSink {
    fn emit(&self, ev: &Event) {
        if !self.timing && !ev.is_deterministic() {
            return;
        }
        let line = ev.to_json(self.timing).to_string();
        if let Ok(mut out) = self.out.lock() {
            // Telemetry volume is coarse (events per run/generation,
            // not per simulated event) — flush per line so tail -f and
            // crashed campaigns both see every record.
            let _ = writeln!(out, "{line}");
            let _ = out.flush();
        }
    }
}

/// In-memory sink capturing rendered JSONL lines — the golden-stream
/// test harness, also handy for embedding.
#[derive(Default)]
pub struct MemSink {
    lines: Mutex<Vec<String>>,
    timing: bool,
}

impl MemSink {
    pub fn new() -> MemSink {
        MemSink::default()
    }

    pub fn with_timing(mut self, timing: bool) -> MemSink {
        self.timing = timing;
        self
    }

    /// The captured stream, one JSON object per line.
    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().map(|l| l.clone()).unwrap_or_default()
    }

    /// The captured stream as one newline-terminated string (byte
    /// comparison form).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for l in self.lines() {
            s.push_str(&l);
            s.push('\n');
        }
        s
    }
}

impl Sink for MemSink {
    fn emit(&self, ev: &Event) {
        if !self.timing && !ev.is_deterministic() {
            return;
        }
        if let Ok(mut lines) = self.lines.lock() {
            lines.push(ev.to_json(self.timing).to_string());
        }
    }
}

/// Broadcast to several sinks (CLI: JSONL file + progress renderer +
/// diagnostic renderer at once).
#[derive(Default)]
pub struct FanoutSink {
    sinks: Vec<Arc<dyn Sink>>,
}

impl FanoutSink {
    pub fn new(sinks: Vec<Arc<dyn Sink>>) -> FanoutSink {
        FanoutSink { sinks }
    }
}

impl Sink for FanoutSink {
    fn emit(&self, ev: &Event) {
        for s in &self.sinks {
            s.emit(ev);
        }
    }
    fn flush(&self) {
        for s in &self.sinks {
            s.flush();
        }
    }
}

// ---------------------------------------------------------------------------
// Handle
// ---------------------------------------------------------------------------

/// Cheap cloneable handle threaded through grid workloads.  A disabled
/// handle (`Telemetry::disabled()`, also `Default`) reduces every
/// emission to one branch; the event-building closure never runs.
#[derive(Clone, Default)]
pub struct Telemetry {
    sink: Option<Arc<dyn Sink>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Telemetry(enabled={})", self.enabled())
    }
}

impl Telemetry {
    pub fn new(sink: Arc<dyn Sink>) -> Telemetry {
        Telemetry { sink: Some(sink) }
    }

    pub fn disabled() -> Telemetry {
        Telemetry::default()
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Emit the event built by `f` — `f` runs only when a sink is
    /// installed.
    #[inline]
    pub fn emit(&self, f: impl FnOnce() -> Event) {
        if let Some(sink) = &self.sink {
            sink.emit(&f());
        }
    }

    pub fn flush(&self) {
        if let Some(sink) = &self.sink {
            sink.flush();
        }
    }
}

// ---------------------------------------------------------------------------
// Global dispatcher (library diagnostics)
// ---------------------------------------------------------------------------

// Deep library code (the simulation kernel, device-backed schedulers)
// has no natural place to thread a handle through, so diagnostics go
// via a process-global dispatcher the CLI installs.  The disabled
// fast path is one relaxed atomic load — the cost `perf_hotpath`
// guards.
static GLOBAL_ENABLED: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<Option<Telemetry>> = Mutex::new(None);

/// Install the process-global telemetry handle (`main.rs` does this
/// once from the CLI flags; tests install a `MemSink`).
pub fn set_global(tel: Telemetry) {
    GLOBAL_ENABLED.store(tel.enabled(), Ordering::Relaxed);
    if let Ok(mut g) = GLOBAL.lock() {
        *g = Some(tel);
    }
}

/// A clone of the installed global handle (disabled if none).
pub fn global() -> Telemetry {
    GLOBAL
        .lock()
        .ok()
        .and_then(|g| g.clone())
        .unwrap_or_default()
}

/// Emit through the global dispatcher; one atomic load when disabled.
#[inline]
pub fn emit_global(f: impl FnOnce() -> Event) {
    if !GLOBAL_ENABLED.load(Ordering::Relaxed) {
        return;
    }
    if let Ok(g) = GLOBAL.lock() {
        if let Some(tel) = g.as_ref() {
            tel.emit(f);
        }
    }
}

/// Library diagnostic (the replacement for scattered `eprintln!`):
/// message formatting is deferred, so disabled runs pay one branch.
#[inline]
pub fn diag(component: &'static str, message: impl FnOnce() -> String) {
    emit_global(|| Event::Diagnostic {
        component: component.to_string(),
        message: message(),
    });
}

// ---------------------------------------------------------------------------
// Timing spans + run metadata helpers
// ---------------------------------------------------------------------------

/// Minimal wall-clock span around a hot-path stage.  Stages already
/// counted in `SimReport` (scheduler invocations, thermal flushes,
/// worker build/reset) accumulate their span totals into the report's
/// `*_wall_ns` fields; campaign-level spans emit [`Event::Span`].
#[derive(Debug, Clone, Copy)]
pub struct SpanTimer {
    t0: Instant,
}

impl SpanTimer {
    #[inline]
    pub fn start() -> SpanTimer {
        SpanTimer { t0: Instant::now() }
    }

    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    #[inline]
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// FNV-1a 64-bit hash of a canonical config serialization — the
/// `config_hash` of [`Event::RunStarted`] and the cache key shape the
/// experiment store (ROADMAP item 2) will reuse.
pub fn config_hash(canonical: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in canonical.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// `git describe --always --dirty` of the working tree, if git and a
/// repository are available — environment metadata for run manifests,
/// never an error.
pub fn git_describe() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let s = String::from_utf8_lossy(&out.stdout).trim().to_string();
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_merge_is_order_independent() {
        let mut a = Counters::new();
        a.add("x", 3);
        a.add("y", 1);
        let mut b = Counters::new();
        b.add("x", 4);
        b.add("z", 2);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.get("x"), 7);
        assert_eq!(ab.get("y"), 1);
        assert_eq!(ab.get("z"), 2);
        assert_eq!(ab.to_json().to_string(), ba.to_json().to_string());
    }

    #[test]
    fn counters_from_report_covers_kernel_counters() {
        let mut r = SimReport::default();
        r.injected_jobs = 10;
        r.completed_jobs = 9;
        r.events_processed = 1234;
        r.thermal_flushes = 7;
        r.deferred_epochs = 70;
        let c = Counters::from_report(&r);
        assert_eq!(c.get("runs"), 1);
        assert_eq!(c.get("completed_jobs"), 9);
        assert_eq!(c.get("events_processed"), 1234);
        assert_eq!(c.get("thermal_flushes"), 7);
        assert_eq!(c.get("deferred_epochs"), 70);
    }

    #[test]
    fn non_timing_sink_drops_wall_clock_events_and_fields() {
        let sink = MemSink::new();
        sink.emit(&Event::SweepProgress {
            completed: 1,
            total: 2,
            sims_per_s: 10.0,
            eta_s: 0.1,
        });
        sink.emit(&Event::RunFinished {
            cmd: "run".into(),
            counters: Counters::new(),
            wall_s: 1.5,
        });
        let lines = sink.lines();
        assert_eq!(lines.len(), 1, "progress must be dropped: {lines:?}");
        assert!(!lines[0].contains("wall_s"), "{}", lines[0]);

        let timed = MemSink::new().with_timing(true);
        timed.emit(&Event::SweepProgress {
            completed: 1,
            total: 2,
            sims_per_s: 10.0,
            eta_s: 0.1,
        });
        timed.emit(&Event::RunFinished {
            cmd: "run".into(),
            counters: Counters::new(),
            wall_s: 1.5,
        });
        let lines = timed.lines();
        assert_eq!(lines.len(), 2);
        assert!(lines[1].contains("wall_s"), "{}", lines[1]);
    }

    #[test]
    fn event_json_is_deterministic_and_kinded() {
        let ev = Event::RunStarted {
            cmd: "sweep".into(),
            config_hash: config_hash("{}"),
            workload_digest: config_hash("workload"),
            seed: 42,
            scheduler: "etf".into(),
            git: None,
        };
        let a = ev.to_json(false).to_string();
        let b = ev.to_json(false).to_string();
        assert_eq!(a, b);
        // Assert on parsed structure, not serialized spelling.
        let j = Json::parse(&a).unwrap();
        assert_eq!(
            j.get("event").and_then(Json::as_str),
            Some("run_started"),
            "{a}"
        );
        assert_eq!(j.get("git"), Some(&Json::Null), "{a}");
        assert_eq!(
            j.get("workload_digest").and_then(Json::as_str),
            Some(config_hash("workload").as_str()),
            "{a}"
        );
    }

    #[test]
    fn counters_json_round_trip_is_exact() {
        let mut c = Counters::new();
        c.add("runs", 3);
        c.add("completed_jobs", 120);
        let back =
            Counters::from_json(&Json::parse(&c.to_json().to_string())
                .unwrap())
            .unwrap();
        assert_eq!(c, back);
        assert!(Counters::from_json(&Json::Null).is_err());
        assert!(Counters::from_json(
            &Json::parse(r#"{"x": 1.5}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn manifest_written_is_deterministic() {
        let ev = Event::ManifestWritten {
            cmd: "sweep".into(),
            key: "abc".into(),
        };
        assert!(ev.is_deterministic());
        assert_eq!(ev.kind(), "manifest_written");
        let j = ev.to_json(false);
        assert_eq!(j.get("key").and_then(Json::as_str), Some("abc"));
    }

    #[test]
    fn disabled_handle_never_builds_events() {
        let tel = Telemetry::disabled();
        let mut built = false;
        tel.emit(|| {
            built = true;
            Event::Span { name: "x".into(), wall_ns: 1 }
        });
        assert!(!built, "closure must not run with no sink installed");
    }

    #[test]
    fn fanout_broadcasts() {
        let a = Arc::new(MemSink::new());
        let b = Arc::new(MemSink::new());
        let fan = FanoutSink::new(vec![a.clone(), b.clone()]);
        fan.emit(&Event::Diagnostic {
            component: "t".into(),
            message: "m".into(),
        });
        assert_eq!(a.lines().len(), 1);
        assert_eq!(b.lines().len(), 1);
    }

    #[test]
    fn config_hash_is_stable_fnv1a() {
        // FNV-1a test vectors.
        assert_eq!(config_hash(""), "cbf29ce484222325");
        assert_eq!(config_hash("a"), "af63dc4c8601ec8c");
        assert_eq!(config_hash("{}"), config_hash("{}"));
        assert_ne!(config_hash("{}"), config_hash("{ }"));
    }
}
