//! Integration tests for the experiment store (README §Experiment
//! store & querying): manifest JSON round-trips, warm-store reruns
//! that skip every simulation while reproducing the report and the
//! default telemetry stream byte-for-byte, resuming a partially
//! persisted sweep, and thread-count-independent store contents.
//!
//! Every test uses a *local* `Telemetry` handle and its own temp
//! store directory — cargo runs integration tests in parallel and
//! both the global dispatcher and the global store are process state.

use ds3r::app::suite::{self, WifiParams};
use ds3r::app::AppGraph;
use ds3r::config::SimConfig;
use ds3r::coordinator::{run_sweep_stored, SweepPoint, SweepResult};
use ds3r::platform::Platform;
use ds3r::store::{
    workload_digest, ExperimentStore, Manifest, StoreCtx, StoreSink,
};
use ds3r::telemetry::{
    Counters, Event, FanoutSink, MemSink, Sink, Telemetry,
};
use ds3r::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn apps() -> Vec<AppGraph> {
    vec![suite::wifi_tx(WifiParams { symbols: 2 })]
}

fn base_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.max_jobs = 30;
    cfg.warmup_jobs = 3;
    cfg.max_sim_us = 5_000_000.0;
    cfg
}

fn grid() -> Vec<SweepPoint> {
    let mut pts = Vec::new();
    for sched in ["etf", "met"] {
        for rate in [2.0, 4.0] {
            pts.push(SweepPoint {
                scheduler: sched.into(),
                rate_per_ms: rate,
                seed: 7,
            });
        }
    }
    pts
}

fn temp_store(tag: &str) -> (PathBuf, Arc<ExperimentStore>) {
    let dir =
        std::env::temp_dir().join(format!("ds3r_int_store_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    let store = ExperimentStore::open(&dir).unwrap();
    (dir, store)
}

fn json_rows(rs: &[SweepResult]) -> Vec<String> {
    rs.iter().map(|r| r.to_json().to_string()).collect()
}

/// One full campaign against `store`: run_started -> stored sweep ->
/// run_finished (so the sink finalizes a manifest), capturing the
/// default (deterministic, non-timing) event stream.
fn campaign(
    store: &Arc<ExperimentStore>,
    threads: usize,
) -> (String, Vec<SweepResult>, Counters) {
    let platform = Platform::table2_soc();
    let apps = apps();
    let cfg = base_cfg();
    let wd = workload_digest(&cfg, &apps, &[]);
    let mem = Arc::new(MemSink::new());
    let sinks: Vec<Arc<dyn Sink>> =
        vec![mem.clone(), Arc::new(StoreSink::new(store.clone()))];
    let tel = Telemetry::new(Arc::new(FanoutSink::new(sinks)));
    tel.emit(|| Event::RunStarted {
        cmd: "sweep".into(),
        config_hash: "cfg-test".into(),
        seed: cfg.seed,
        scheduler: cfg.scheduler.clone(),
        workload_digest: wd.clone(),
        git: None,
    });
    let ctx = StoreCtx { store: store.clone(), workload_digest: wd };
    let (results, counters) = run_sweep_stored(
        &platform,
        &apps,
        &cfg,
        &grid(),
        threads,
        &tel,
        Some(&ctx),
    )
    .unwrap();
    tel.emit(|| Event::RunFinished {
        cmd: "sweep".into(),
        counters: counters.clone(),
        wall_s: 0.0,
    });
    (mem.dump(), results, counters)
}

/// `(relative path, contents)` of every file under `dir`, sorted —
/// the full store fingerprint.
fn tree(dir: &Path) -> Vec<(String, String)> {
    fn walk(
        root: &Path,
        dir: &Path,
        out: &mut Vec<(String, String)>,
    ) {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(root, &p, out);
            } else {
                let rel = p
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .into_owned();
                out.push((rel, std::fs::read_to_string(&p).unwrap()));
            }
        }
    }
    let mut out = Vec::new();
    walk(dir, dir, &mut out);
    out
}

#[test]
fn manifest_round_trips_through_json() {
    let mut counters = Counters::new();
    counters.add("runs", 4);
    counters.add("completed_jobs", 120);
    let m = Manifest {
        cmd: "sweep".into(),
        config_hash: "abc123".into(),
        workload_digest: "wd0".into(),
        seed: 7,
        scheduler: "etf".into(),
        git: Some("v1-3-gdeadbee".into()),
        counters,
        point_keys: vec!["k1".into(), "k2".into()],
        result: Json::parse(r#"{"points": 4}"#).unwrap(),
    };
    let back = Manifest::from_json(&m.to_json()).unwrap();
    assert_eq!(m, back);
    // ... and through actual serialized text, the on-disk format.
    let text = m.to_json().to_string_pretty();
    let again = Manifest::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(m, again);
    assert_eq!(m.key(), again.key());
}

#[test]
fn warm_rerun_skips_every_simulation_and_reproduces_output() {
    let (dir, store) = temp_store("warm");
    let n = grid().len() as u64;
    let (s_cold, r_cold, c_cold) = campaign(&store, 2);
    assert_eq!(store.session_hits(), 0);
    assert_eq!(store.session_misses(), n);
    assert!(store.last_manifest_key().is_some());
    // A fresh handle over the same directory: every point must come
    // from the cache, with report, counters and the default stream
    // unchanged by a byte.
    let store2 = ExperimentStore::open(&dir).unwrap();
    let (s_warm, r_warm, c_warm) = campaign(&store2, 8);
    assert_eq!(store2.session_misses(), 0, "a warm rerun simulated");
    assert_eq!(store2.session_hits(), n);
    assert_eq!(json_rows(&r_cold), json_rows(&r_warm));
    assert_eq!(c_cold, c_warm);
    assert_eq!(s_cold, s_warm, "default stream must not see the cache");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn partial_store_resume_completes_only_missing_points() {
    let (dir, store) = temp_store("resume");
    let platform = Platform::table2_soc();
    let apps = apps();
    let cfg = base_cfg();
    let wd = workload_digest(&cfg, &apps, &[]);
    let tel = Telemetry::disabled();
    let all = grid();
    // Simulate a killed campaign: only the first half of the grid got
    // persisted before the process died.
    let ctx =
        StoreCtx { store: store.clone(), workload_digest: wd.clone() };
    run_sweep_stored(
        &platform,
        &apps,
        &cfg,
        &all[..2],
        2,
        &tel,
        Some(&ctx),
    )
    .unwrap();
    assert_eq!(store.session_misses(), 2);
    // Resume over the full grid with a fresh handle: the stored half
    // hits, only the missing half simulates, and the merged report
    // equals an uncached full run.
    let store2 = ExperimentStore::open(&dir).unwrap();
    let ctx2 = StoreCtx { store: store2.clone(), workload_digest: wd };
    let (resumed, rc) = run_sweep_stored(
        &platform,
        &apps,
        &cfg,
        &all,
        2,
        &tel,
        Some(&ctx2),
    )
    .unwrap();
    assert_eq!(store2.session_hits(), 2);
    assert_eq!(store2.session_misses(), 2);
    let (cold, cc) =
        run_sweep_stored(&platform, &apps, &cfg, &all, 2, &tel, None)
            .unwrap();
    assert_eq!(json_rows(&resumed), json_rows(&cold));
    assert_eq!(rc, cc);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_contents_are_identical_for_1_and_8_threads() {
    let (d1, s1) = temp_store("threads1");
    let (d8, s8) = temp_store("threads8");
    campaign(&s1, 1);
    campaign(&s8, 8);
    let t1 = tree(&d1);
    let t8 = tree(&d8);
    assert!(!t1.is_empty());
    assert_eq!(t1, t8, "store contents depend on thread count");
    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d8);
}

#[test]
fn verify_and_gc_pass_on_a_freshly_written_store() {
    let (dir, store) = temp_store("verify");
    campaign(&store, 2);
    let v = store.verify().unwrap();
    assert!(v.ok(), "mismatches: {:?}", v.mismatches);
    assert!(v.manifests_checked >= 1);
    assert_eq!(v.points_checked, grid().len());
    let gc = store.gc().unwrap();
    assert_eq!(gc.dropped_points, 0);
    assert_eq!(gc.dropped_rows, 0);
    assert_eq!(gc.kept_points, grid().len());
    let _ = std::fs::remove_dir_all(&dir);
}
