//! Worker-reuse equivalence suite: the behavioural contract of the
//! batched grid-evaluation engine.
//!
//! A [`SimWorker`] that is `reset` between runs must be **bit-identical**
//! to a freshly built `Simulation` — across every registered scheduler,
//! every scenario preset, different setups (platform re-binding), and
//! any thread count of the pooled fan-outs.  These tests are the reason
//! the PR 2 golden traces did not need re-blessing for this refactor.

use ds3r::app::suite::{self, WifiParams};
use ds3r::app::AppGraph;
use ds3r::config::SimConfig;
use ds3r::coordinator::{self, parallel_map_pooled};
use ds3r::platform::Platform;
use ds3r::scenario::presets;
use ds3r::sched;
use ds3r::sim::{SimSetup, SimWorker, Simulation};
use ds3r::stats::SimReport;

fn wifi_apps() -> Vec<AppGraph> {
    vec![suite::wifi_tx(WifiParams { symbols: 2 })]
}

fn base_cfg(sched: &str, rate: f64, jobs: usize) -> SimConfig {
    let mut c = SimConfig::default();
    c.scheduler = sched.into();
    c.injection_rate_per_ms = rate;
    c.max_jobs = jobs;
    c.warmup_jobs = jobs / 10;
    c
}

/// Every observable a fresh run and a reused-worker run must share,
/// bit-for-bit.
fn assert_bit_identical(ctx: &str, a: &SimReport, b: &SimReport) {
    assert_eq!(a.injected_jobs, b.injected_jobs, "{ctx}: injected");
    assert_eq!(a.completed_jobs, b.completed_jobs, "{ctx}: completed");
    assert_eq!(
        a.events_processed, b.events_processed,
        "{ctx}: events"
    );
    assert_eq!(a.tasks_executed, b.tasks_executed, "{ctx}: tasks");
    assert_eq!(
        a.sched_invocations, b.sched_invocations,
        "{ctx}: sched invocations"
    );
    assert_eq!(
        a.job_latencies_us, b.job_latencies_us,
        "{ctx}: latencies"
    );
    assert_eq!(
        a.per_app_latencies_us, b.per_app_latencies_us,
        "{ctx}: per-app latencies"
    );
    assert_eq!(
        a.total_energy_j.to_bits(),
        b.total_energy_j.to_bits(),
        "{ctx}: energy"
    );
    assert_eq!(
        a.peak_temp_c.to_bits(),
        b.peak_temp_c.to_bits(),
        "{ctx}: peak temp"
    );
    assert_eq!(a.pe_utilization, b.pe_utilization, "{ctx}: utilization");
    assert_eq!(a.scenario_events, b.scenario_events, "{ctx}: sc events");
    assert_eq!(a.phases.len(), b.phases.len(), "{ctx}: phase count");
    for (pa, pb) in a.phases.iter().zip(&b.phases) {
        assert_eq!(pa.label, pb.label, "{ctx}: phase label");
        assert_eq!(pa.jobs_completed, pb.jobs_completed, "{ctx}");
        assert_eq!(
            pa.energy_j.to_bits(),
            pb.energy_j.to_bits(),
            "{ctx}: phase energy"
        );
    }
}

/// Fresh-build vs worker-reuse bit-identity across **all registered
/// schedulers** (the `builtin_names()` registry): the worker runs a
/// decoy config first so any state leak through reset would surface.
#[test]
fn worker_reuse_is_bit_identical_for_all_registered_schedulers() {
    let p = Platform::table2_soc();
    let apps = wifi_apps();
    let artifacts = ds3r::runtime::artifacts_available(
        &ds3r::runtime::default_artifacts_dir(),
    );
    let decoy = base_cfg("rr", 6.0, 40);
    let setup = SimSetup::new(&p, &apps, &decoy).unwrap();
    let mut slot: Option<SimWorker> = None;
    for &name in sched::builtin_names() {
        if name == "etf-xla" && !artifacts {
            continue; // needs AOT artifacts on disk
        }
        let cfg = base_cfg(name, 3.0, 60);
        let fresh = Simulation::build(&p, &apps, &cfg)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .run();
        // Dirty the worker with the decoy, then reset into `cfg`.
        let w = SimWorker::obtain(&mut slot, &setup, &decoy).unwrap();
        w.run(&setup);
        w.reset(&setup, &cfg).unwrap();
        w.run(&setup);
        let reused = w.take_report();
        assert_bit_identical(name, &reused, &fresh);
    }
}

/// Same contract across **all five scenario presets** (timeline
/// execution, phase accounting, fault/hotplug, power-budget changes and
/// scheduler hot-swaps all pass through the reset path).
#[test]
fn worker_reuse_is_bit_identical_for_all_scenario_presets() {
    let p = Platform::table2_soc();
    let apps = wifi_apps();
    let plain = base_cfg("etf", 4.0, 150);
    let setup = SimSetup::new(&p, &apps, &plain).unwrap();
    let mut slot: Option<SimWorker> = None;
    let all = presets::all();
    assert_eq!(all.len(), 5, "preset roster changed — update the test");
    for sc in all {
        let name = sc.name.clone();
        let mut cfg = plain.clone();
        cfg.scenario = Some(sc);
        let fresh = Simulation::build(&p, &apps, &cfg)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .run();
        let w = SimWorker::obtain(&mut slot, &setup, &plain).unwrap();
        w.run(&setup);
        w.reset(&setup, &cfg).unwrap();
        w.run(&setup);
        let reused = w.take_report();
        assert_bit_identical(&name, &reused, &fresh);
        assert_eq!(reused.scenario, name);
    }
}

/// Re-binding one worker across *different* platform setups (the DSE
/// evaluator's cross-genome reuse) must equal fresh builds on each.
#[test]
fn worker_rebinds_across_platform_setups() {
    let p_cool = Platform::table2_soc();
    let mut p_hot = Platform::table2_soc();
    p_hot.t_ambient = 50.0;
    let apps = wifi_apps();
    let cfg = base_cfg("etf", 3.0, 80);
    let s_cool = SimSetup::new(&p_cool, &apps, &cfg).unwrap();
    let s_hot =
        SimSetup::with_owned_platform(p_hot.clone(), &apps, &cfg).unwrap();
    let mut slot: Option<SimWorker> = None;
    for _ in 0..2 {
        let w = SimWorker::obtain(&mut slot, &s_cool, &cfg).unwrap();
        w.run(&s_cool);
        let cool = w.take_report();
        let w = SimWorker::obtain(&mut slot, &s_hot, &cfg).unwrap();
        w.run(&s_hot);
        let hot = w.take_report();
        let fresh_cool =
            Simulation::build(&p_cool, &apps, &cfg).unwrap().run();
        let fresh_hot =
            Simulation::build(&p_hot, &apps, &cfg).unwrap().run();
        assert_bit_identical("cool", &cool, &fresh_cool);
        assert_bit_identical("hot", &hot, &fresh_hot);
        assert!(hot.peak_temp_c > cool.peak_temp_c);
    }
}

/// The pooled fan-out pins one worker per thread; 1 thread vs 8 threads
/// must produce identical outputs even when workers are reused across
/// many heterogeneous points.
#[test]
fn pooled_fanout_is_thread_count_invariant() {
    let p = Platform::table2_soc();
    let apps = wifi_apps();
    let base = base_cfg("etf", 2.0, 40);
    let setup = SimSetup::new(&p, &apps, &base).unwrap();
    let setup = &setup;
    let points: Vec<(u64, f64)> = (0..12)
        .map(|i| (i as u64, 1.0 + (i % 4) as f64))
        .collect();
    let run_all = |threads: usize| -> Vec<(Vec<f64>, u64, u64)> {
        parallel_map_pooled(
            &points,
            threads,
            || None::<SimWorker>,
            |slot, _, &(seed, rate)| {
                let mut cfg = base.clone();
                cfg.seed = seed;
                cfg.injection_rate_per_ms = rate;
                let w = SimWorker::obtain(slot, setup, &cfg)?;
                let r = w.run(setup);
                Ok((
                    r.job_latencies_us.clone(),
                    r.events_processed,
                    r.total_energy_j.to_bits(),
                ))
            },
        )
        .into_iter()
        .map(|r| r.unwrap())
        .collect()
    };
    let serial = run_all(1);
    let wide = run_all(8);
    assert_eq!(serial, wide);
}

/// End-to-end: `run_sweep` (now pooled) against the serial reference,
/// and across thread counts.
#[test]
fn run_sweep_pooled_matches_across_thread_counts() {
    let p = Platform::table2_soc();
    let apps = wifi_apps();
    let mut base = SimConfig::default();
    base.max_jobs = 40;
    base.warmup_jobs = 5;
    let pts =
        coordinator::fig3_points(&["etf", "met", "rr"], &[0.5, 2.0], 11);
    let serial =
        coordinator::run_sweep(&p, &apps, &base, &pts, 1).unwrap();
    let wide = coordinator::run_sweep(&p, &apps, &base, &pts, 8).unwrap();
    for (a, b) in serial.iter().zip(&wide) {
        assert_eq!(a.avg_latency_us.to_bits(), b.avg_latency_us.to_bits());
        assert_eq!(a.p95_latency_us.to_bits(), b.p95_latency_us.to_bits());
        assert_eq!(
            a.energy_per_job_mj.to_bits(),
            b.energy_per_job_mj.to_bits()
        );
        assert_eq!(a.completed_jobs, b.completed_jobs);
        assert_eq!(a.peak_temp_c.to_bits(), b.peak_temp_c.to_bits());
    }
}

/// The learn pipeline end-to-end through pooled workers: training and
/// evaluation must produce byte-identical artifacts for 1 vs 8 threads
/// (worker pinning may hand different points to different workers, but
/// results land in input order and every run is reset-clean).
#[test]
fn learn_pipeline_artifacts_identical_across_thread_counts() {
    use ds3r::learn::{self, LearnConfig};
    let p = Platform::table2_soc();
    let apps = wifi_apps();
    let run = |threads: usize| {
        let mut lc = LearnConfig::default();
        lc.seeds = vec![3, 9];
        lc.rates_per_ms = vec![1.5, 3.0];
        lc.rounds = 2;
        lc.epochs = 3;
        lc.sim.max_jobs = 30;
        lc.sim.warmup_jobs = 3;
        lc.threads = threads;
        let (model, summary) =
            learn::train_policy(&p, &apps, &lc).unwrap();
        let artifact = model.to_json().to_string();
        let report = learn::evaluate(&p, &apps, &lc, &model).unwrap();
        (artifact, summary.samples, report)
    };
    let (art1, samples1, rep1) = run(1);
    let (art8, samples8, rep8) = run(8);
    assert_eq!(samples1, samples8, "datasets diverged across threads");
    assert_eq!(art1, art8, "policy artifact bytes diverged");
    assert_eq!(rep1.rows, rep8.rows, "eval rows diverged");
    assert_eq!(
        rep1.agreement.to_bits(),
        rep8.agreement.to_bits(),
        "agreement diverged"
    );
}
