//! Job generator: injects application instances into the simulation.
//!
//! "The simulation is driven by the job generator which injects instances
//! of an application to the simulator following a given probability
//! distribution" (paper §2).  Supported inter-arrival processes:
//! Poisson (exponential), periodic, and uniform; the application for each
//! job is drawn from the configured mix weights.  A recorded trace can be
//! replayed for exact cross-scheduler comparisons.

use crate::config::ArrivalKind;
use crate::rng::Rng;

/// One planned job arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobArrival {
    pub at_us: f64,
    pub app: usize,
}

/// Generates the arrival stream.
pub struct JobGen {
    kind: ArrivalKind,
    /// Mean inter-arrival time (µs).
    mean_iat_us: f64,
    weights: Vec<f64>,
    rng: Rng,
    next_at: f64,
    emitted: usize,
    max_jobs: usize,
    /// Replay source: when set, arrivals come verbatim from this trace
    /// (recorded by [`JobGen::record_trace`] or loaded from JSON) —
    /// exact cross-scheduler comparisons with identical arrivals.
    trace: Option<Vec<JobArrival>>,
}

impl JobGen {
    /// `rate_per_ms` is the aggregate injection rate over all apps;
    /// `weights` picks the app per job (empty = uniform over `n_apps`).
    pub fn new(
        kind: ArrivalKind,
        rate_per_ms: f64,
        n_apps: usize,
        weights: &[f64],
        max_jobs: usize,
        seed: u64,
    ) -> JobGen {
        assert!(rate_per_ms > 0.0);
        assert!(n_apps > 0);
        let weights = if weights.is_empty() {
            vec![1.0; n_apps]
        } else {
            assert_eq!(
                weights.len(),
                n_apps,
                "app_weights length must match workload size"
            );
            weights.to_vec()
        };
        JobGen {
            kind,
            mean_iat_us: 1000.0 / rate_per_ms,
            weights,
            rng: Rng::new(seed ^ 0x10B6_E75A_17C0_FFEE),
            next_at: 0.0,
            emitted: 0,
            max_jobs,
            trace: None,
        }
    }

    /// Replay an explicit arrival trace (`max_jobs` still truncates when
    /// non-zero).  Arrival times must be strictly increasing.
    pub fn from_trace(trace: Vec<JobArrival>, max_jobs: usize) -> JobGen {
        debug_assert!(trace
            .windows(2)
            .all(|w| w[1].at_us > w[0].at_us));
        JobGen {
            kind: ArrivalKind::Periodic, // unused in replay mode
            mean_iat_us: 0.0,
            weights: vec![1.0],
            rng: Rng::new(0),
            next_at: 0.0,
            emitted: 0,
            max_jobs,
            trace: Some(trace),
        }
    }

    /// Load a trace from JSON: `{"arrivals": [{"at_us": t, "app": a}, ...]}`.
    pub fn from_trace_json(
        j: &crate::util::json::Json,
        max_jobs: usize,
    ) -> crate::Result<JobGen> {
        let mut trace = Vec::new();
        for a in j.req_arr("arrivals")? {
            trace.push(JobArrival {
                at_us: a.req_f64("at_us")?,
                app: a.req_f64("app")? as usize,
            });
        }
        if trace.windows(2).any(|w| w[1].at_us <= w[0].at_us) {
            return Err(crate::Error::Config(
                "trace arrivals must be strictly increasing".into(),
            ));
        }
        Ok(JobGen::from_trace(trace, max_jobs))
    }

    /// Serialize a trace to JSON (the inverse of `from_trace_json`).
    pub fn trace_to_json(trace: &[JobArrival]) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut arr = Vec::with_capacity(trace.len());
        for a in trace {
            let mut o = Json::obj();
            o.set("at_us", Json::Num(a.at_us));
            o.set("app", Json::Num(a.app as f64));
            arr.push(o);
        }
        let mut j = Json::obj();
        j.set("arrivals", Json::Arr(arr));
        j
    }

    /// Change the aggregate injection rate mid-stream (scenario engine:
    /// rate steps/ramps).  Takes effect from the next draw — the arrival
    /// already in flight keeps its inter-arrival gap.  No-op in trace
    /// replay mode.
    pub fn set_rate(&mut self, rate_per_ms: f64) {
        assert!(rate_per_ms > 0.0, "set_rate({rate_per_ms})");
        if self.trace.is_some() {
            return;
        }
        self.mean_iat_us = 1000.0 / rate_per_ms;
    }

    /// Current aggregate injection rate (jobs/ms); 0 in replay mode.
    pub fn rate_per_ms(&self) -> f64 {
        if self.mean_iat_us > 0.0 {
            1000.0 / self.mean_iat_us
        } else {
            0.0
        }
    }

    /// Switch the application-mix weights mid-stream (scenario engine:
    /// app-mix switches).  Length must match the workload size; the
    /// simulation validates this before the run starts.  No-op in trace
    /// replay mode (replayed arrivals carry their app explicitly).
    pub fn set_weights(&mut self, weights: &[f64]) {
        if self.trace.is_some() {
            return;
        }
        assert_eq!(
            weights.len(),
            self.weights.len(),
            "app-weights length must match workload size"
        );
        self.weights = weights.to_vec();
    }

    /// Next arrival, or `None` when `max_jobs` have been emitted.
    pub fn next(&mut self) -> Option<JobArrival> {
        if self.max_jobs > 0 && self.emitted >= self.max_jobs {
            return None;
        }
        if let Some(trace) = &self.trace {
            let a = trace.get(self.emitted).copied();
            if a.is_some() {
                self.emitted += 1;
            }
            return a;
        }
        let iat = match self.kind {
            ArrivalKind::Poisson => {
                self.rng.exp(1.0 / self.mean_iat_us)
            }
            ArrivalKind::Periodic => self.mean_iat_us,
            ArrivalKind::Uniform => self
                .rng
                .uniform(0.5 * self.mean_iat_us, 1.5 * self.mean_iat_us),
        };
        self.next_at += iat;
        self.emitted += 1;
        let app = self.rng.choose_weighted(&self.weights);
        Some(JobArrival { at_us: self.next_at, app })
    }

    /// Drain the whole stream (trace recording).
    pub fn record_trace(mut self) -> Vec<JobArrival> {
        let mut out = Vec::new();
        while let Some(a) = self.next() {
            out.push(a);
        }
        out
    }

    pub fn emitted(&self) -> usize {
        self.emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate_is_calibrated() {
        let mut g = JobGen::new(
            ArrivalKind::Poisson,
            5.0, // 5 jobs/ms -> mean IAT 200 µs
            1,
            &[],
            20_000,
            7,
        );
        let mut last = 0.0;
        let mut sum = 0.0;
        let mut n = 0;
        while let Some(a) = g.next() {
            sum += a.at_us - last;
            last = a.at_us;
            n += 1;
        }
        let mean = sum / n as f64;
        assert!((mean - 200.0).abs() < 5.0, "mean IAT {mean}");
    }

    #[test]
    fn periodic_is_exact() {
        let mut g =
            JobGen::new(ArrivalKind::Periodic, 2.0, 1, &[], 10, 7);
        let times: Vec<f64> =
            std::iter::from_fn(|| g.next().map(|a| a.at_us)).collect();
        for (i, t) in times.iter().enumerate() {
            assert!((t - 500.0 * (i + 1) as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut g =
            JobGen::new(ArrivalKind::Uniform, 1.0, 1, &[], 5000, 11);
        let mut last = 0.0;
        while let Some(a) = g.next() {
            let iat = a.at_us - last;
            assert!((500.0..=1500.0).contains(&iat), "iat {iat}");
            last = a.at_us;
        }
    }

    #[test]
    fn respects_max_jobs() {
        let mut g =
            JobGen::new(ArrivalKind::Poisson, 1.0, 1, &[], 17, 1);
        let n = std::iter::from_fn(|| g.next()).count();
        assert_eq!(n, 17);
        assert_eq!(g.emitted(), 17);
    }

    #[test]
    fn app_mix_follows_weights() {
        let mut g = JobGen::new(
            ArrivalKind::Poisson,
            1.0,
            3,
            &[1.0, 0.0, 3.0],
            40_000,
            13,
        );
        let mut counts = [0usize; 3];
        while let Some(a) = g.next() {
            counts[a.app] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn trace_replay_is_verbatim() {
        let recorded = JobGen::new(ArrivalKind::Poisson, 3.0, 2, &[], 50, 9)
            .record_trace();
        let replayed =
            JobGen::from_trace(recorded.clone(), 0).record_trace();
        assert_eq!(recorded, replayed);
        // Truncation works.
        let short = JobGen::from_trace(recorded.clone(), 10).record_trace();
        assert_eq!(short.len(), 10);
        assert_eq!(short[..], recorded[..10]);
    }

    #[test]
    fn trace_json_roundtrip() {
        let recorded =
            JobGen::new(ArrivalKind::Uniform, 2.0, 3, &[], 30, 4)
                .record_trace();
        let j = JobGen::trace_to_json(&recorded);
        let back = JobGen::from_trace_json(&j, 0).unwrap().record_trace();
        assert_eq!(recorded, back);
    }

    #[test]
    fn trace_json_rejects_unsorted() {
        let j = crate::util::json::Json::parse(
            r#"{"arrivals": [{"at_us": 5, "app": 0}, {"at_us": 3, "app": 0}]}"#,
        )
        .unwrap();
        assert!(JobGen::from_trace_json(&j, 0).is_err());
    }

    #[test]
    fn set_rate_changes_spacing_mid_stream() {
        let mut g =
            JobGen::new(ArrivalKind::Periodic, 1.0, 1, &[], 20, 7);
        for _ in 0..10 {
            g.next();
        }
        assert_eq!(g.rate_per_ms(), 1.0);
        g.set_rate(4.0); // 250 µs spacing from here on
        assert_eq!(g.rate_per_ms(), 4.0);
        let mut last = 10_000.0;
        while let Some(a) = g.next() {
            assert!((a.at_us - last - 250.0).abs() < 1e-9);
            last = a.at_us;
        }
    }

    #[test]
    fn set_rate_is_noop_in_replay_mode() {
        let recorded =
            JobGen::new(ArrivalKind::Poisson, 3.0, 1, &[], 20, 9)
                .record_trace();
        let mut g = JobGen::from_trace(recorded.clone(), 0);
        g.set_rate(50.0);
        assert_eq!(g.rate_per_ms(), 0.0);
        assert_eq!(g.record_trace(), recorded);
    }

    #[test]
    fn set_weights_switches_mix() {
        let mut g = JobGen::new(
            ArrivalKind::Poisson,
            1.0,
            2,
            &[1.0, 0.0],
            20_000,
            13,
        );
        for _ in 0..100 {
            assert_eq!(g.next().unwrap().app, 0);
        }
        g.set_weights(&[0.0, 1.0]);
        while let Some(a) = g.next() {
            assert_eq!(a.app, 1);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let trace = |seed| {
            JobGen::new(ArrivalKind::Poisson, 2.0, 2, &[], 100, seed)
                .record_trace()
        };
        assert_eq!(trace(5), trace(5));
        assert_ne!(trace(5), trace(6));
    }

    #[test]
    fn arrivals_strictly_increase() {
        let trace = JobGen::new(ArrivalKind::Poisson, 10.0, 1, &[], 1000, 3)
            .record_trace();
        for w in trace.windows(2) {
            assert!(w[1].at_us > w[0].at_us);
        }
    }
}
