//! Small shared utilities: JSON, summary statistics, ASCII plotting.

pub mod json;
pub mod plot;

/// Summary statistics over a sample (latency distributions, etc.).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute summary statistics. Returns a zeroed summary for empty input.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count: xs.len(),
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[sorted.len() - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Available hardware parallelism with a conservative fallback — the
/// single resolve-thread-count policy behind `cli::default_threads`
/// and the DSE evaluator.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Format a microsecond quantity with an adaptive unit.
pub fn fmt_us(us: f64) -> String {
    if us >= 1e6 {
        format!("{:.2} s", us / 1e6)
    } else if us >= 1e3 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{us:.1} us")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.count, 10);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn summary_of_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&xs, 0.5), 50.0);
        assert_eq!(percentile_sorted(&xs, 0.95), 95.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 100.0);
    }

    #[test]
    fn fmt_us_units() {
        assert_eq!(fmt_us(12.0), "12.0 us");
        assert_eq!(fmt_us(1500.0), "1.50 ms");
        assert_eq!(fmt_us(2_500_000.0), "2.50 s");
    }
}
