//! Artifact runtime: execute the AOT-compiled JAX/Pallas artifact
//! contracts.
//!
//! The original deployment loads the **HLO text** artifacts (see
//! `python/compile/aot.py`) through a PJRT client.  The offline build has
//! no PJRT/XLA toolchain, so this module ships a **native f32
//! interpreter** of the two artifact contracts instead: the padded
//! shapes, sentinel handling and f32 arithmetic mirror the device
//! execution exactly (DESIGN.md §5), so results agree with the python
//! goldens to the same tolerance the device path is held to.
//!
//! The artifact *files* are still required: `load` refuses to run without
//! the `artifacts/*.hlo.txt` produced by `make artifacts`, keeping the
//! build/runtime contract (and the golden tests that gate on it) honest.
//!
//! Each artifact struct ([`DtpmArtifact`], [`EtfArtifact`]) owns the
//! fixed-shape padding/unpadding logic of its AOT contract.

use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// DTPM artifact contract (must match `python/compile/kernels/thermal.py`).
pub const DTPM_K: usize = 16;
pub const DTPM_N: usize = 32;
pub const DTPM_P: usize = 16;

/// ETF artifact contract (must match `python/compile/kernels/etf.py`).
pub const ETF_I: usize = 64;
pub const ETF_J: usize = 16;

/// Large finite sentinel used instead of +inf when padding (keeps the
/// device matrix finite so argmin reductions avoid NaN edge cases and
/// the values survive JSON goldens).
pub const PAD_SENTINEL: f32 = 1e30;

/// Resolve the artifacts directory: `$DS3R_ARTIFACTS`, else `artifacts/`
/// relative to the current directory, else relative to the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DS3R_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if the AOT artifacts are present (tests skip gracefully if the
/// user has not run `make artifacts`).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("dtpm_step.hlo.txt").exists()
        && dir.join("etf_matrix.hlo.txt").exists()
}

/// Check that an artifact file exists (the load-time half of the AOT
/// contract; the compute half is interpreted natively below).
fn require_artifact(path: &Path) -> Result<()> {
    if !path.exists() {
        return Err(Error::Runtime(format!(
            "artifact {} not found — run `make artifacts` first",
            path.display()
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// DTPM artifact
// ---------------------------------------------------------------------------

/// Outputs of one batched DTPM step (unpadded to platform dimensions).
#[derive(Debug, Clone)]
pub struct DtpmStepOut {
    /// `[k][node]` next above-ambient temperatures.
    pub t_next: Vec<Vec<f64>>,
    /// `[k][pe]` leakage power (W).
    pub p_leak: Vec<Vec<f64>>,
    /// `[k][pe]` total power (W).
    pub p_total: Vec<Vec<f64>>,
    /// `[k]` SoC power (W).
    pub p_sum: Vec<f64>,
}

/// The batched power/thermal epoch update of
/// `python/compile/model.py::dtpm_step_model`, interpreted natively in
/// f32 over the artifact's padded shapes.
pub struct DtpmArtifact {
    /// Padded constant operands (platform-dependent, set via `set_model`).
    a_pad: Vec<f32>,
    b_pad: Vec<f32>,
    pe_node_pad: Vec<f32>,
    k1_pad: Vec<f32>,
    k2_pad: Vec<f32>,
    n_nodes: usize,
    n_pes: usize,
    pub calls: u64,
}

impl DtpmArtifact {
    pub const K: usize = DTPM_K;

    /// Load the artifact; `set_model` must be called before `step`.
    pub fn load(dir: &Path) -> Result<DtpmArtifact> {
        require_artifact(&dir.join("dtpm_step.hlo.txt"))?;
        Ok(DtpmArtifact {
            a_pad: vec![0.0; DTPM_N * DTPM_N],
            b_pad: vec![0.0; DTPM_N * DTPM_P],
            pe_node_pad: vec![0.0; DTPM_P * DTPM_N],
            k1_pad: vec![0.0; DTPM_P],
            k2_pad: vec![0.0; DTPM_P],
            n_nodes: 0,
            n_pes: 0,
            calls: 0,
        })
    }

    /// Install the platform's thermal model and leakage coefficients.
    ///
    /// `k1` must already be the *effective* k1 (ambient offset folded in,
    /// see `thermal::RcModel::leak_k1_effective`).
    pub fn set_model(
        &mut self,
        rc: &crate::thermal::RcModel,
        k1_eff: &[f64],
        k2: &[f64],
    ) -> Result<()> {
        if rc.n > DTPM_N || rc.n_pes > DTPM_P {
            return Err(Error::Runtime(format!(
                "platform ({} nodes, {} pes) exceeds artifact padding \
                 ({DTPM_N}, {DTPM_P})",
                rc.n, rc.n_pes
            )));
        }
        self.a_pad = rc.a_padded_f32(DTPM_N, DTPM_N);
        self.b_pad = rc.b_padded_f32(DTPM_N, DTPM_P);
        self.pe_node_pad = rc.pe_node_padded_f32(DTPM_P, DTPM_N);
        self.k1_pad = vec![0.0; DTPM_P];
        self.k2_pad = vec![0.0; DTPM_P];
        for i in 0..rc.n_pes {
            self.k1_pad[i] = k1_eff[i] as f32;
            self.k2_pad[i] = k2[i] as f32;
        }
        self.n_nodes = rc.n;
        self.n_pes = rc.n_pes;
        Ok(())
    }

    /// Execute one batched step for `candidates.len() <= K` DVFS
    /// candidates.  Each candidate supplies per-PE dynamic power and
    /// voltage; `theta` is the shared current state (above-ambient °C).
    ///
    /// Per candidate row `k` (all arithmetic in f32, artifact contract):
    ///
    /// ```text
    ///   t_pe    = pe_node · theta
    ///   p_leak  = k1 * V * exp(k2 * t_pe)
    ///   p_total = p_dyn + p_leak
    ///   t_next  = A · theta + B · p_total
    ///   p_sum   = Σ p_total
    /// ```
    pub fn step(
        &mut self,
        theta: &[f64],
        candidates: &[(Vec<f64>, Vec<f64>)], // (p_dyn, volt) per candidate
    ) -> Result<DtpmStepOut> {
        assert!(self.n_nodes > 0, "set_model not called");
        let k_used = candidates.len();
        if k_used == 0 || k_used > DTPM_K {
            return Err(Error::Runtime(format!(
                "bad candidate count {k_used} (1..={DTPM_K})"
            )));
        }
        debug_assert_eq!(theta.len(), self.n_nodes);

        // Padded state row (shared across candidates).
        let mut th = vec![0.0f32; DTPM_N];
        for i in 0..self.n_nodes {
            th[i] = theta[i] as f32;
        }

        let mut t_next = Vec::with_capacity(k_used);
        let mut p_leak_out = Vec::with_capacity(k_used);
        let mut p_total_out = Vec::with_capacity(k_used);
        let mut p_sum = Vec::with_capacity(k_used);
        for (pdk, vk) in candidates.iter().take(k_used) {
            // Per-PE temperature via the one-hot node map.
            let mut p_tot = vec![0.0f32; DTPM_P];
            let mut p_lk = vec![0.0f32; DTPM_P];
            for p in 0..DTPM_P {
                let mut t_pe = 0.0f32;
                let row = &self.pe_node_pad[p * DTPM_N..(p + 1) * DTPM_N];
                for (m, t) in row.iter().zip(&th) {
                    t_pe += m * t;
                }
                let (pd, v) = if p < self.n_pes {
                    (pdk[p] as f32, vk[p] as f32)
                } else {
                    (0.0, 0.0)
                };
                let leak =
                    self.k1_pad[p] * v * (self.k2_pad[p] * t_pe).exp();
                p_lk[p] = leak;
                p_tot[p] = pd + leak;
            }
            // t_next = A theta + B p_total.
            let mut tn = vec![0.0f32; DTPM_N];
            for i in 0..DTPM_N {
                let mut acc = 0.0f32;
                let arow = &self.a_pad[i * DTPM_N..(i + 1) * DTPM_N];
                for (a, t) in arow.iter().zip(&th) {
                    acc += a * t;
                }
                let brow = &self.b_pad[i * DTPM_P..(i + 1) * DTPM_P];
                for (b, p) in brow.iter().zip(&p_tot) {
                    acc += b * p;
                }
                tn[i] = acc;
            }
            let sum: f32 = p_tot.iter().sum();
            t_next.push(
                tn[..self.n_nodes].iter().map(|&x| x as f64).collect(),
            );
            p_leak_out.push(
                p_lk[..self.n_pes].iter().map(|&x| x as f64).collect(),
            );
            p_total_out.push(
                p_tot[..self.n_pes].iter().map(|&x| x as f64).collect(),
            );
            p_sum.push(sum as f64);
        }
        self.calls += 1;
        Ok(DtpmStepOut { t_next, p_leak: p_leak_out, p_total: p_total_out, p_sum })
    }
}

// ---------------------------------------------------------------------------
// ETF artifact
// ---------------------------------------------------------------------------

/// The ETF finish-time matrix of `python/compile/model.py::etf_model`,
/// interpreted natively in f32 over the artifact's padded shapes.
pub struct EtfArtifact {
    pub calls: u64,
}

impl EtfArtifact {
    /// Max ready tasks per device call (artifact row padding).
    pub const MAX_TASKS: usize = ETF_I;
    /// Max PEs (artifact column padding).
    pub const MAX_PES: usize = ETF_J;

    pub fn load(dir: &Path) -> Result<EtfArtifact> {
        require_artifact(&dir.join("etf_matrix.hlo.txt"))?;
        Ok(EtfArtifact { calls: 0 })
    }

    /// Compute `finish[i][j] = max(avail[j], ready[i][j]) + exec[i][j]`
    /// for `n x m` real entries (row-major `ready`/`exec`).  Unsupported
    /// pairs must carry `f64::INFINITY` in `exec`; they come back as
    /// `f64::INFINITY`.
    pub fn finish_matrix(
        &mut self,
        avail: &[f64],
        ready: &[f64],
        exec: &[f64],
        n: usize,
        m: usize,
    ) -> Result<Vec<f64>> {
        if n > ETF_I || m > ETF_J {
            return Err(Error::Runtime(format!(
                "ready list {n}x{m} exceeds artifact padding {ETF_I}x{ETF_J}"
            )));
        }
        debug_assert_eq!(avail.len(), m);
        debug_assert_eq!(ready.len(), n * m);
        debug_assert_eq!(exec.len(), n * m);

        self.calls += 1;
        let mut out = vec![f64::INFINITY; n * m];
        for i in 0..n {
            for j in 0..m {
                let e = exec[i * m + j];
                let ex: f32 =
                    if e.is_finite() { e as f32 } else { PAD_SENTINEL };
                let fin =
                    (avail[j] as f32).max(ready[i * m + j] as f32) + ex;
                // Anything that saturated the sentinel is "unsupported".
                out[i * m + j] = if fin >= PAD_SENTINEL * 0.5 {
                    f64::INFINITY
                } else {
                    fin as f64
                };
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full numeric round-trip tests against the python goldens live in
    // rust/tests/integration_runtime.rs (they need `make artifacts`).
    // Here: pure host-side helpers.

    #[test]
    fn artifacts_dir_resolution_env() {
        std::env::set_var("DS3R_ARTIFACTS", "/tmp/ds3r-test-artifacts");
        assert_eq!(
            default_artifacts_dir(),
            PathBuf::from("/tmp/ds3r-test-artifacts")
        );
        std::env::remove_var("DS3R_ARTIFACTS");
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = require_artifact(Path::new("/nonexistent/foo.hlo.txt"))
            .err()
            .expect("must fail");
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "msg: {msg}");
    }

    #[test]
    fn etf_contract_math_without_files() {
        // The interpreter itself is file-independent; exercise the
        // padded-shape semantics directly.
        let mut art = EtfArtifact { calls: 0 };
        let avail = vec![10.0, 0.0];
        let ready = vec![0.0, 20.0, 5.0, 5.0];
        let exec = vec![3.0, 4.0, f64::INFINITY, 1.0];
        let fin = art.finish_matrix(&avail, &ready, &exec, 2, 2).unwrap();
        assert_eq!(fin[0], 13.0); // max(10, 0) + 3
        assert_eq!(fin[1], 24.0); // max(0, 20) + 4
        assert!(fin[2].is_infinite()); // unsupported
        assert_eq!(fin[3], 6.0); // max(0, 5) + 1
        assert_eq!(art.calls, 1);
    }

    #[test]
    fn dtpm_contract_math_without_files() {
        use crate::platform::Platform;
        use crate::thermal::RcModel;
        let platform = Platform::table2_soc();
        let rc = RcModel::new(&platform, 10_000.0);
        let (k1, k2): (Vec<f64>, Vec<f64>) = platform
            .pes
            .iter()
            .map(|pe| {
                let c = &platform.classes[pe.class];
                (rc.leak_k1_effective(c.leak_k1, c.leak_k2), c.leak_k2)
            })
            .unzip();
        let mut art = DtpmArtifact {
            a_pad: vec![0.0; DTPM_N * DTPM_N],
            b_pad: vec![0.0; DTPM_N * DTPM_P],
            pe_node_pad: vec![0.0; DTPM_P * DTPM_N],
            k1_pad: vec![0.0; DTPM_P],
            k2_pad: vec![0.0; DTPM_P],
            n_nodes: 0,
            n_pes: 0,
            calls: 0,
        };
        art.set_model(&rc, &k1, &k2).unwrap();

        // Native f64 reference vs the f32 interpreter.
        let theta = vec![10.0f64; rc.n];
        let p_dyn: Vec<f64> =
            (0..rc.n_pes).map(|i| 0.3 + 0.1 * i as f64).collect();
        let volts = vec![1.1f64; rc.n_pes];
        let p_total: Vec<f64> = (0..rc.n_pes)
            .map(|i| {
                let t_pe = theta[rc.pe_node[i]];
                p_dyn[i] + k1[i] * volts[i] * (k2[i] * t_pe).exp()
            })
            .collect();
        let native_next = rc.step(&theta, &p_total);

        let out = art
            .step(&theta, &[(p_dyn.clone(), volts.clone())])
            .unwrap();
        for i in 0..rc.n {
            assert!(
                (out.t_next[0][i] - native_next[i]).abs() < 1e-3,
                "node {i}: interp {} vs native {}",
                out.t_next[0][i],
                native_next[i]
            );
        }
        let want_sum: f64 = p_total.iter().sum();
        assert!((out.p_sum[0] - want_sum).abs() < 1e-3);
    }
}
