//! Append-only manifest index (`<store>/index.jsonl`).
//!
//! One compact JSONL row per distinct manifest key — enough identity
//! to answer `ds3r query` filters without opening every manifest
//! file.  Appends are idempotent by key, so reruns of an identical
//! campaign never duplicate rows and 1-vs-8-thread runs leave
//! byte-identical index files.  `store gc` is the only writer that
//! rewrites the file in place.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::{Path, PathBuf};

use super::manifest::Manifest;
use crate::util::json::{u64_from_json, u64_to_json, Json};
use crate::{Error, Result};

/// One index row: the identity fields of a stored [`Manifest`].
#[derive(Debug, Clone, PartialEq)]
pub struct IndexRow {
    pub key: String,
    pub cmd: String,
    pub config_hash: String,
    pub workload_digest: String,
    pub seed: u64,
    pub scheduler: String,
}

impl IndexRow {
    pub fn from_manifest(m: &Manifest) -> IndexRow {
        IndexRow {
            key: m.key(),
            cmd: m.cmd.clone(),
            config_hash: m.config_hash.clone(),
            workload_digest: m.workload_digest.clone(),
            seed: m.seed,
            scheduler: m.scheduler.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("key", Json::Str(self.key.clone()))
            .set("cmd", Json::Str(self.cmd.clone()))
            .set("config_hash", Json::Str(self.config_hash.clone()))
            .set(
                "workload_digest",
                Json::Str(self.workload_digest.clone()),
            )
            .set("seed", u64_to_json(self.seed))
            .set("scheduler", Json::Str(self.scheduler.clone()));
        j
    }

    pub fn from_json(j: &Json) -> Result<IndexRow> {
        Ok(IndexRow {
            key: j.req_str("key")?.to_string(),
            cmd: j.req_str("cmd")?.to_string(),
            config_hash: j.req_str("config_hash")?.to_string(),
            workload_digest: j.req_str("workload_digest")?.to_string(),
            seed: j.get("seed").and_then(u64_from_json).ok_or_else(
                || Error::Json("index row: bad seed".into()),
            )?,
            scheduler: j.req_str("scheduler")?.to_string(),
        })
    }
}

/// In-memory mirror of `index.jsonl` plus its on-disk path.
#[derive(Debug)]
pub struct Index {
    path: PathBuf,
    rows: Vec<IndexRow>,
    keys: BTreeSet<String>,
    /// Whether [`Index::open`] dropped a torn trailing line — the
    /// signature of a crash mid-append.  `store fsck` reports it.
    salvaged_tail: bool,
}

impl Index {
    /// Load the index at `path` (an absent file is an empty index).
    ///
    /// Crash-safe: appends are the only non-atomic writes the store
    /// performs, so a crash can tear exactly one line — the last one.
    /// An unparseable **final** non-empty line is therefore salvaged
    /// (dropped, the file rewritten with the intact rows, a
    /// diagnostic emitted); an unparseable line anywhere *else*
    /// signals real corruption and still fails hard (`store fsck`
    /// quarantines such files).
    pub fn open(path: &Path) -> Result<Index> {
        let mut idx = Index {
            path: path.to_path_buf(),
            rows: Vec::new(),
            keys: BTreeSet::new(),
            salvaged_tail: false,
        };
        if path.exists() {
            let text = std::fs::read_to_string(path)?;
            let lines: Vec<&str> = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty())
                .collect();
            let last = lines.len().wrapping_sub(1);
            for (i, line) in lines.iter().enumerate() {
                let parsed = Json::parse(line)
                    .and_then(|j| IndexRow::from_json(&j));
                match parsed {
                    Ok(row) => {
                        idx.keys.insert(row.key.clone());
                        idx.rows.push(row);
                    }
                    Err(e) if i == last => {
                        crate::telemetry::diag("store", || {
                            format!(
                                "index: dropped torn trailing line \
                                 ({e})"
                            )
                        });
                        idx.salvaged_tail = true;
                    }
                    Err(e) => return Err(e),
                }
            }
            if idx.salvaged_tail {
                // Rewrite without the torn tail so the next append
                // starts on a clean line.
                idx.rewrite(|_| true)?;
            }
        }
        Ok(idx)
    }

    /// Whether opening this index dropped a torn trailing line.
    pub fn salvaged_tail(&self) -> bool {
        self.salvaged_tail
    }

    pub fn rows(&self) -> &[IndexRow] {
        &self.rows
    }

    pub fn contains(&self, key: &str) -> bool {
        self.keys.contains(key)
    }

    /// Append a row unless its key is already indexed (idempotent).
    /// Returns whether the row was new.
    pub fn append(&mut self, row: IndexRow) -> Result<bool> {
        if self.keys.contains(&row.key) {
            return Ok(false);
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", row.to_json().to_string())?;
        self.keys.insert(row.key.clone());
        self.rows.push(row);
        Ok(true)
    }

    /// Drop every row failing `keep` and rewrite the file atomically
    /// (`store gc` path).  Returns how many rows were dropped.
    pub fn rewrite(
        &mut self,
        keep: impl Fn(&IndexRow) -> bool,
    ) -> Result<usize> {
        let before = self.rows.len();
        self.rows.retain(&keep);
        self.keys = self.rows.iter().map(|r| r.key.clone()).collect();
        let mut text = String::new();
        for row in &self.rows {
            text.push_str(&row.to_json().to_string());
            text.push('\n');
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        std::fs::write(&tmp, text)?;
        std::fs::rename(&tmp, &self.path)?;
        Ok(before - self.rows.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(key: &str, seed: u64) -> IndexRow {
        IndexRow {
            key: key.into(),
            cmd: "sweep".into(),
            config_hash: "ch".into(),
            workload_digest: "wd".into(),
            seed,
            scheduler: "etf".into(),
        }
    }

    #[test]
    fn append_is_idempotent_and_survives_reopen() {
        let dir = std::env::temp_dir().join("ds3r_store_index_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("index.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut idx = Index::open(&path).unwrap();
        assert!(idx.append(row("a", 1)).unwrap());
        assert!(idx.append(row("b", 2)).unwrap());
        assert!(!idx.append(row("a", 1)).unwrap(), "dup must be a no-op");
        assert_eq!(idx.rows().len(), 2);

        let idx2 = Index::open(&path).unwrap();
        assert_eq!(idx2.rows(), idx.rows());
        assert!(idx2.contains("a") && idx2.contains("b"));

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_trailing_line_is_salvaged_and_the_file_healed() {
        let dir =
            std::env::temp_dir().join("ds3r_store_index_torn_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("index.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut idx = Index::open(&path).unwrap();
        idx.append(row("a", 1)).unwrap();
        idx.append(row("b", 2)).unwrap();
        // Simulate a crash mid-append: a truncated JSON fragment with
        // no trailing newline.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"key\":\"c\",\"cmd\":\"swe");
        std::fs::write(&path, &text).unwrap();

        let idx2 = Index::open(&path).unwrap();
        assert!(idx2.salvaged_tail());
        assert_eq!(idx2.rows().len(), 2);
        assert!(idx2.contains("a") && idx2.contains("b"));

        // The salvage rewrote the file: a reopen is clean, and a new
        // append lands on its own line.
        let mut idx3 = Index::open(&path).unwrap();
        assert!(!idx3.salvaged_tail());
        assert!(idx3.append(row("c", 3)).unwrap());
        let idx4 = Index::open(&path).unwrap();
        assert_eq!(idx4.rows().len(), 3);
        assert!(idx4.contains("c"));

        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_file_corruption_still_fails_hard() {
        let dir =
            std::env::temp_dir().join("ds3r_store_index_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("index.jsonl");
        std::fs::write(
            &path,
            format!(
                "not json at all\n{}\n",
                row("a", 1).to_json().to_string()
            ),
        )
        .unwrap();
        assert!(
            Index::open(&path).is_err(),
            "corruption before the final line must not be salvaged"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rewrite_drops_rows_and_round_trips() {
        let dir = std::env::temp_dir().join("ds3r_store_index_rw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("index.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut idx = Index::open(&path).unwrap();
        idx.append(row("a", 1)).unwrap();
        idx.append(row("b", 2)).unwrap();
        idx.append(row("c", 3)).unwrap();
        assert_eq!(idx.rewrite(|r| r.key != "b").unwrap(), 1);
        assert!(!idx.contains("b"));

        let idx2 = Index::open(&path).unwrap();
        assert_eq!(idx2.rows().len(), 2);
        assert!(idx2.contains("a") && idx2.contains("c"));

        let _ = std::fs::remove_file(&path);
    }
}
