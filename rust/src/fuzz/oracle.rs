//! Invariant oracles over a finished [`SimReport`] — the
//! property-test invariants of `rust/tests/prop_invariants.rs` lifted
//! into reusable library checks, so the fuzz tournament (and any other
//! harness) can interrogate **every** run it executes, not just the
//! curated property seeds.
//!
//! Each oracle is named; a [`Violation`] carries the oracle name plus a
//! deterministic detail string, so two runs of the same `(config,
//! seed)` produce byte-identical verdicts — the contract the repro
//! replay test pins.

use crate::config::SimConfig;
use crate::stats::SimReport;
use crate::telemetry::Counters;

/// One failed invariant: which oracle, and a deterministic description
/// of the evidence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub oracle: String,
    pub detail: String,
}

impl Violation {
    fn new(oracle: &str, detail: String) -> Violation {
        Violation { oracle: oracle.to_string(), detail }
    }
}

/// Names of every oracle [`check`] runs, in check order.
pub const ORACLE_NAMES: &[&str] = &[
    "phase_partition",
    "no_job_loss",
    "energy_integral",
    "finite_stats",
    "counter_consistency",
];

/// Run every oracle against one finished report.  `cfg` must be the
/// config the run executed under (the energy oracle only applies when
/// traces were captured; the phase oracle only when a scenario ran).
pub fn check(report: &SimReport, cfg: &SimConfig) -> Vec<Violation> {
    let mut v = Vec::new();
    check_phase_partition(report, cfg, &mut v);
    check_no_job_loss(report, &mut v);
    check_energy_integral(report, cfg, &mut v);
    check_finite_stats(report, &mut v);
    check_counter_consistency(report, &mut v);
    v
}

/// Scenario phases must exactly partition `[0, sim_time_us]`: start at
/// zero, contiguous within 1e-9, last end at the simulated end.
fn check_phase_partition(
    r: &SimReport,
    cfg: &SimConfig,
    out: &mut Vec<Violation>,
) {
    const O: &str = "phase_partition";
    if cfg.scenario.is_none() {
        return;
    }
    if r.phases.is_empty() {
        out.push(Violation::new(O, "scenario run reported no phases".into()));
        return;
    }
    if r.phases[0].start_us != 0.0 {
        out.push(Violation::new(
            O,
            format!("first phase starts at {} != 0", r.phases[0].start_us),
        ));
    }
    for w in r.phases.windows(2) {
        if (w[0].end_us - w[1].start_us).abs() >= 1e-9 {
            out.push(Violation::new(
                O,
                format!(
                    "phase gap: '{}' ends {} but '{}' starts {}",
                    w[0].label, w[0].end_us, w[1].label, w[1].start_us
                ),
            ));
        }
    }
    for ph in &r.phases {
        if ph.end_us < ph.start_us {
            out.push(Violation::new(
                O,
                format!(
                    "phase '{}' runs backwards: {}..{}",
                    ph.label, ph.start_us, ph.end_us
                ),
            ));
        }
    }
    let last = r.phases.last().expect("non-empty");
    if (last.end_us - r.sim_time_us).abs() >= 1e-9 {
        out.push(Violation::new(
            O,
            format!(
                "phases end at {} but simulation ended at {}",
                last.end_us, r.sim_time_us
            ),
        ));
    }
}

/// Every injected job must complete — faults are outages, not sinks.
fn check_no_job_loss(r: &SimReport, out: &mut Vec<Violation>) {
    const O: &str = "no_job_loss";
    if r.completed_jobs != r.injected_jobs {
        out.push(Violation::new(
            O,
            format!(
                "completed {} of {} injected jobs",
                r.completed_jobs, r.injected_jobs
            ),
        ));
    }
}

/// With traces captured, total energy must equal the integral of the
/// per-epoch power trace (relative tolerance 1e-6).
fn check_energy_integral(
    r: &SimReport,
    cfg: &SimConfig,
    out: &mut Vec<Violation>,
) {
    const O: &str = "energy_integral";
    if !cfg.capture_traces || r.trace.is_empty() {
        return;
    }
    let mut integral = 0.0;
    let mut last_t = 0.0;
    for tr in &r.trace {
        integral += tr.power_w * (tr.t_us - last_t) * 1e-6;
        last_t = tr.t_us;
    }
    let tol = 1e-6 * r.total_energy_j.max(1e-9);
    if (integral - r.total_energy_j).abs() > tol {
        out.push(Violation::new(
            O,
            format!(
                "total energy {} J != power integral {} J",
                r.total_energy_j, integral
            ),
        ));
    }
}

/// No NaN/inf anywhere a statistic is reported; energies and times
/// non-negative; latencies strictly positive.
fn check_finite_stats(r: &SimReport, out: &mut Vec<Violation>) {
    const O: &str = "finite_stats";
    let mut bad = |name: &str, x: f64, nonneg: bool| {
        if !x.is_finite() || (nonneg && x < 0.0) {
            out.push(Violation::new(O, format!("{name} = {x}")));
        }
    };
    bad("sim_time_us", r.sim_time_us, true);
    bad("total_energy_j", r.total_energy_j, true);
    bad("avg_power_w", r.avg_power_w, true);
    bad("peak_temp_c", r.peak_temp_c, false);
    for (i, &l) in r.job_latencies_us.iter().enumerate() {
        if !l.is_finite() || l <= 0.0 {
            out.push(Violation::new(
                O,
                format!("job latency [{i}] = {l}"),
            ));
            break; // one representative per run keeps details bounded
        }
    }
    for ph in &r.phases {
        for (name, x) in [
            ("avg_latency_us", ph.avg_latency_us),
            ("p95_latency_us", ph.p95_latency_us),
            ("energy_j", ph.energy_j),
            ("avg_power_w", ph.avg_power_w),
        ] {
            if !x.is_finite() || x < 0.0 {
                out.push(Violation::new(
                    O,
                    format!("phase '{}' {name} = {x}", ph.label),
                ));
            }
        }
    }
}

/// The report's kernel counters must be internally consistent and
/// project onto [`Counters::from_report`] exactly — the report and the
/// telemetry counter stream may never disagree.
fn check_counter_consistency(r: &SimReport, out: &mut Vec<Violation>) {
    const O: &str = "counter_consistency";
    if r.sched_fallbacks > r.sched_decisions {
        out.push(Violation::new(
            O,
            format!(
                "{} fallbacks exceed {} decisions",
                r.sched_fallbacks, r.sched_decisions
            ),
        ));
    }
    if r.completed_jobs > 0 && r.tasks_executed == 0 {
        out.push(Violation::new(
            O,
            format!(
                "{} jobs completed with zero tasks executed",
                r.completed_jobs
            ),
        ));
    }
    let c = Counters::from_report(r);
    for (key, reported) in [
        ("injected_jobs", r.injected_jobs as u64),
        ("completed_jobs", r.completed_jobs as u64),
        ("events_processed", r.events_processed),
        ("tasks_executed", r.tasks_executed),
        ("sched_decisions", r.sched_decisions),
        ("sched_fallbacks", r.sched_fallbacks),
        ("scenario_events", r.scenario_events),
    ] {
        if c.get(key) != reported {
            out.push(Violation::new(
                O,
                format!(
                    "counter '{key}' = {} but report field = {reported}",
                    c.get(key)
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::suite::{self, WifiParams};
    use crate::platform::Platform;
    use crate::scenario::presets;
    use crate::sim::Simulation;

    fn run(cfg: &SimConfig) -> SimReport {
        let p = Platform::table2_soc();
        let apps = vec![suite::wifi_tx(WifiParams { symbols: 2 })];
        Simulation::build(&p, &apps, cfg).unwrap().run()
    }

    #[test]
    fn clean_runs_pass_every_oracle() {
        let mut cfg = SimConfig::default();
        cfg.max_jobs = 40;
        cfg.warmup_jobs = 0;
        cfg.capture_traces = true;
        cfg.scenario = Some(presets::pe_failure());
        let r = run(&cfg);
        let v = check(&r, &cfg);
        assert!(v.is_empty(), "violations on a clean run: {v:?}");
    }

    #[test]
    fn corrupted_reports_are_caught() {
        let mut cfg = SimConfig::default();
        cfg.max_jobs = 30;
        cfg.warmup_jobs = 0;
        cfg.capture_traces = true;
        cfg.scenario = Some(presets::budget_throttle());
        let mut r = run(&cfg);
        assert!(check(&r, &cfg).is_empty());

        let clean = r.clone();
        r.completed_jobs -= 1;
        assert!(check(&r, &cfg)
            .iter()
            .any(|v| v.oracle == "no_job_loss"));

        let mut r = clean.clone();
        r.total_energy_j *= 1.5;
        assert!(check(&r, &cfg)
            .iter()
            .any(|v| v.oracle == "energy_integral"));

        let mut r = clean.clone();
        r.avg_power_w = f64::NAN;
        assert!(check(&r, &cfg)
            .iter()
            .any(|v| v.oracle == "finite_stats"));

        let mut r = clean.clone();
        r.phases[0].start_us = 5.0;
        assert!(check(&r, &cfg)
            .iter()
            .any(|v| v.oracle == "phase_partition"));

        let mut r = clean;
        r.sched_fallbacks = r.sched_decisions + 1;
        assert!(check(&r, &cfg)
            .iter()
            .any(|v| v.oracle == "counter_consistency"));
    }

    #[test]
    fn oracle_names_cover_emitted_violations() {
        // Every Violation a corrupted report produces names a listed
        // oracle — the tournament's per-oracle tally can't miss one.
        let mut cfg = SimConfig::default();
        cfg.max_jobs = 20;
        cfg.warmup_jobs = 0;
        cfg.capture_traces = true;
        cfg.scenario = Some(presets::thermal_soak());
        let mut r = run(&cfg);
        r.completed_jobs = 0;
        r.avg_power_w = f64::INFINITY;
        r.phases.clear();
        for v in check(&r, &cfg) {
            assert!(
                ORACLE_NAMES.contains(&v.oracle.as_str()),
                "unknown oracle '{}'",
                v.oracle
            );
        }
    }
}
