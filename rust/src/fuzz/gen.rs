//! Seeded random scenario generation: the adversarial counterpart of
//! the hand-written presets in [`crate::scenario::presets`].
//!
//! A [`FuzzConfig`] bounds the chaos — event counts, rate/ambient/cap
//! ranges, the fraction of PEs a fault storm may take down, the
//! scheduler hot-swap pool — and [`generate`] draws one [`Scenario`]
//! per `(seed, case)` pair from the crate's deterministic RNG.  Every
//! generated scenario is valid **by construction** (and re-checked
//! through [`Scenario::validate`]/[`Scenario::validate_for`] before it
//! leaves this module):
//!
//! * timestamps walk strictly forward, so the non-decreasing rule holds;
//! * rate steps/ramps are suppressed while a previous ramp window is
//!   still open (the validator rejects rate events inside one);
//! * fault storms only fail PEs whose class keeps at least one live
//!   member — no generated timeline can strand a task with nowhere to
//!   run — and every failure is paired with a later hotplug
//!   [`Action::PeRestore`], so the no-job-loss oracle is a fair check
//!   of the simulator rather than of the workload;
//! * app-weight churn always emits `n_apps` non-negative weights with a
//!   positive sum, ambient swings stay inside the validator's physical
//!   range, and power caps oscillate between `Some(cap)` and `None`.

use crate::platform::Platform;
use crate::rng::Rng;
use crate::scenario::{Action, Scenario};
use crate::util::json::Json;
use crate::{Error, Result};

/// Bounds for the random scenario generator.  JSON round-trips via
/// [`FuzzConfig::to_json`]/[`FuzzConfig::from_json`] (missing keys keep
/// their defaults, like [`crate::config::SimConfig`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FuzzConfig {
    /// Root seed: case `i` draws from `Rng::new(seed).fork(i)`.
    pub seed: u64,
    /// Number of scenarios one tournament generates.
    pub cases: usize,
    /// Minimum generator moves per scenario (a fault storm is one move
    /// but emits paired fail/restore events).
    pub min_events: usize,
    /// Maximum generator moves per scenario.
    pub max_events: usize,
    /// Timeline length the moves are spread over (µs).  Restores may
    /// land slightly past it.
    pub horizon_us: f64,
    /// Injection-rate range for steps and ramp targets (jobs/ms).
    pub rate_min_per_ms: f64,
    pub rate_max_per_ms: f64,
    /// Longest ramp window (µs).
    pub max_ramp_us: f64,
    /// Ambient-swing range (°C); must stay inside the validator's
    /// physical [-55, 150] band.
    pub ambient_min_c: f64,
    pub ambient_max_c: f64,
    /// Power-budget oscillation range (W).
    pub cap_min_w: f64,
    pub cap_max_w: f64,
    /// Cap on the fraction of PEs failed at any instant.
    pub max_failed_frac: f64,
    /// Scheduler names for hot-swap events (must be creatable by
    /// [`crate::sched::create`] without on-disk artifacts).
    pub swap_pool: Vec<String>,
    /// Jobs per simulated case (`SimConfig::max_jobs`).
    pub jobs: usize,
    /// Latency threshold above which a job counts as a deadline miss
    /// in tournament scoring (µs).  A scoring construct, not a
    /// simulator concept.
    pub deadline_us: f64,
}

impl Default for FuzzConfig {
    fn default() -> FuzzConfig {
        FuzzConfig {
            seed: 42,
            cases: 200,
            min_events: 4,
            max_events: 14,
            horizon_us: 120_000.0,
            rate_min_per_ms: 0.5,
            rate_max_per_ms: 6.0,
            max_ramp_us: 30_000.0,
            ambient_min_c: 15.0,
            ambient_max_c: 60.0,
            cap_min_w: 2.5,
            cap_max_w: 8.0,
            max_failed_frac: 0.5,
            swap_pool: vec![
                "etf".into(),
                "met".into(),
                "met-lb".into(),
                "heft".into(),
                "rr".into(),
            ],
            jobs: 80,
            deadline_us: 20_000.0,
        }
    }
}

impl FuzzConfig {
    pub fn validate(&self) -> Result<()> {
        if self.cases == 0 {
            return Err(Error::Config("fuzz: cases must be >= 1".into()));
        }
        if self.min_events == 0 || self.min_events > self.max_events {
            return Err(Error::Config(format!(
                "fuzz: want 1 <= min_events <= max_events, got {}..{}",
                self.min_events, self.max_events
            )));
        }
        if !(self.horizon_us.is_finite() && self.horizon_us > 0.0) {
            return Err(Error::Config(
                "fuzz: horizon_us must be finite and > 0".into(),
            ));
        }
        if !(self.rate_min_per_ms > 0.0
            && self.rate_min_per_ms <= self.rate_max_per_ms
            && self.rate_max_per_ms.is_finite())
        {
            return Err(Error::Config(format!(
                "fuzz: want 0 < rate_min <= rate_max, got {}..{}",
                self.rate_min_per_ms, self.rate_max_per_ms
            )));
        }
        if !(self.max_ramp_us.is_finite() && self.max_ramp_us > 0.0) {
            return Err(Error::Config(
                "fuzz: max_ramp_us must be finite and > 0".into(),
            ));
        }
        if !(self.ambient_min_c >= -55.0
            && self.ambient_min_c <= self.ambient_max_c
            && self.ambient_max_c <= 150.0)
        {
            return Err(Error::Config(format!(
                "fuzz: ambient range {}..{} outside [-55, 150]",
                self.ambient_min_c, self.ambient_max_c
            )));
        }
        if !(self.cap_min_w > 0.0
            && self.cap_min_w <= self.cap_max_w
            && self.cap_max_w.is_finite())
        {
            return Err(Error::Config(format!(
                "fuzz: want 0 < cap_min <= cap_max, got {}..{}",
                self.cap_min_w, self.cap_max_w
            )));
        }
        if !(0.0..=0.9).contains(&self.max_failed_frac) {
            return Err(Error::Config(format!(
                "fuzz: max_failed_frac {} outside [0, 0.9]",
                self.max_failed_frac
            )));
        }
        if self.swap_pool.iter().any(|s| s.is_empty()) {
            return Err(Error::Config(
                "fuzz: empty scheduler name in swap_pool".into(),
            ));
        }
        if self.jobs == 0 {
            return Err(Error::Config("fuzz: jobs must be >= 1".into()));
        }
        if !(self.deadline_us.is_finite() && self.deadline_us > 0.0) {
            return Err(Error::Config(
                "fuzz: deadline_us must be finite and > 0".into(),
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("seed", crate::util::json::u64_to_json(self.seed))
            .set("cases", Json::Num(self.cases as f64))
            .set("min_events", Json::Num(self.min_events as f64))
            .set("max_events", Json::Num(self.max_events as f64))
            .set("horizon_us", Json::Num(self.horizon_us))
            .set("rate_min_per_ms", Json::Num(self.rate_min_per_ms))
            .set("rate_max_per_ms", Json::Num(self.rate_max_per_ms))
            .set("max_ramp_us", Json::Num(self.max_ramp_us))
            .set("ambient_min_c", Json::Num(self.ambient_min_c))
            .set("ambient_max_c", Json::Num(self.ambient_max_c))
            .set("cap_min_w", Json::Num(self.cap_min_w))
            .set("cap_max_w", Json::Num(self.cap_max_w))
            .set("max_failed_frac", Json::Num(self.max_failed_frac))
            .set(
                "swap_pool",
                Json::Arr(
                    self.swap_pool
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set("jobs", Json::Num(self.jobs as f64))
            .set("deadline_us", Json::Num(self.deadline_us));
        j
    }

    /// Parse, with missing keys keeping their defaults; the result is
    /// re-validated.
    pub fn from_json(j: &Json) -> Result<FuzzConfig> {
        let d = FuzzConfig::default();
        let num =
            |k: &str, v: f64| j.get(k).and_then(Json::as_f64).unwrap_or(v);
        let cfg = FuzzConfig {
            seed: num("seed", d.seed as f64) as u64,
            cases: num("cases", d.cases as f64) as usize,
            min_events: num("min_events", d.min_events as f64) as usize,
            max_events: num("max_events", d.max_events as f64) as usize,
            horizon_us: num("horizon_us", d.horizon_us),
            rate_min_per_ms: num("rate_min_per_ms", d.rate_min_per_ms),
            rate_max_per_ms: num("rate_max_per_ms", d.rate_max_per_ms),
            max_ramp_us: num("max_ramp_us", d.max_ramp_us),
            ambient_min_c: num("ambient_min_c", d.ambient_min_c),
            ambient_max_c: num("ambient_max_c", d.ambient_max_c),
            cap_min_w: num("cap_min_w", d.cap_min_w),
            cap_max_w: num("cap_max_w", d.cap_max_w),
            max_failed_frac: num("max_failed_frac", d.max_failed_frac),
            swap_pool: match j.get("swap_pool") {
                Some(Json::Arr(xs)) => xs
                    .iter()
                    .map(|x| {
                        x.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Config(
                                "fuzz: swap_pool entries must be strings"
                                    .into(),
                            )
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                _ => d.swap_pool,
            },
            jobs: num("jobs", d.jobs as f64) as usize,
            deadline_us: num("deadline_us", d.deadline_us),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<FuzzConfig> {
        FuzzConfig::from_json(&Json::parse_file(path)?)
    }
}

/// Generate the `case`-th scenario of a fuzz campaign.  Deterministic
/// in `(cfg.seed, case)`; independent of `cfg.cases`, so growing a
/// campaign extends it without disturbing earlier cases.
pub fn generate(
    cfg: &FuzzConfig,
    platform: &Platform,
    n_apps: usize,
    case: usize,
) -> Result<Scenario> {
    cfg.validate()?;
    let mut root = Rng::new(cfg.seed);
    let mut rng = root.fork(case as u64);
    let n_pes = platform.n_pes();
    let class_of: Vec<usize> =
        platform.pes.iter().map(|pe| pe.class).collect();
    let mut alive_per_class = vec![0usize; platform.classes.len()];
    for &c in &class_of {
        alive_per_class[c] += 1;
    }
    let max_failed = (((n_pes as f64) * cfg.max_failed_frac) as usize)
        .min(n_pes.saturating_sub(1));

    let span = cfg.max_events - cfg.min_events;
    let n_moves =
        cfg.min_events + rng.below(span as u64 + 1) as usize;
    let gap_mean = cfg.horizon_us / (n_moves as f64 + 1.0);

    let mut sc = Scenario::new(
        format!("fuzz-s{}-c{case}", cfg.seed),
        format!(
            "generated scenario (seed {}, case {case}): rate \
             steps/ramps, fault storms with hotplug recovery, ambient \
             swings, power-budget oscillation, app churn, scheduler \
             swaps",
            cfg.seed
        ),
    )
    .event(
        0.0,
        Action::SetRate {
            per_ms: rng.uniform(cfg.rate_min_per_ms, cfg.rate_max_per_ms),
        },
    );

    let mut t = 0.0_f64;
    let mut ramp_until = 0.0_f64;
    let mut cap_on = false;
    let mut failed: Vec<usize> = Vec::new();
    // (restore time, pe) for every in-flight failure; flushed in time
    // order ahead of each move so timestamps stay non-decreasing.
    let mut pending: Vec<(f64, usize)> = Vec::new();

    for _ in 0..n_moves {
        t += rng.uniform(0.25, 1.75) * gap_mean;
        pending.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        while let Some(&(rt, pe)) = pending.first() {
            if rt > t {
                break;
            }
            sc = sc.event(rt, Action::PeRestore { pe });
            failed.retain(|&x| x != pe);
            alive_per_class[class_of[pe]] += 1;
            pending.remove(0);
        }

        let can_rate = t > ramp_until;
        let fail_candidates: Vec<usize> = (0..n_pes)
            .filter(|pe| {
                !failed.contains(pe) && alive_per_class[class_of[*pe]] >= 2
            })
            .collect();
        let can_fail =
            failed.len() < max_failed && !fail_candidates.is_empty();
        // Move kinds: [rate step, rate ramp, fault storm, ambient,
        // power cap, app weights, scheduler swap].
        let weights = [
            if can_rate { 2.0 } else { 0.0 },
            if can_rate { 1.5 } else { 0.0 },
            if can_fail { 1.5 } else { 0.0 },
            1.0,
            1.0,
            if n_apps >= 2 { 1.0 } else { 0.0 },
            if cfg.swap_pool.is_empty() { 0.0 } else { 1.0 },
        ];
        match rng.choose_weighted(&weights) {
            0 => {
                sc = sc.event(
                    t,
                    Action::SetRate {
                        per_ms: rng.uniform(
                            cfg.rate_min_per_ms,
                            cfg.rate_max_per_ms,
                        ),
                    },
                );
            }
            1 => {
                let over_us = rng.uniform(0.2, 1.0) * cfg.max_ramp_us;
                sc = sc.event(
                    t,
                    Action::RampRate {
                        to_per_ms: rng.uniform(
                            cfg.rate_min_per_ms,
                            cfg.rate_max_per_ms,
                        ),
                        over_us,
                    },
                );
                ramp_until = ramp_until.max(t + over_us);
            }
            2 => {
                let storm = 1 + rng.below(2) as usize;
                let mut candidates = fail_candidates;
                for _ in 0..storm {
                    if failed.len() >= max_failed || candidates.is_empty()
                    {
                        break;
                    }
                    let pick = candidates
                        .remove(rng.below(candidates.len() as u64)
                            as usize);
                    sc = sc.event(t, Action::PeFail { pe: pick });
                    failed.push(pick);
                    alive_per_class[class_of[pick]] -= 1;
                    let recover =
                        t + rng.uniform(0.05, 0.30) * cfg.horizon_us;
                    pending.push((recover, pick));
                    // A storm may not orphan a class either.
                    candidates.retain(|pe| {
                        alive_per_class[class_of[*pe]] >= 2
                    });
                }
            }
            3 => {
                sc = sc.event(
                    t,
                    Action::SetAmbient {
                        t_c: rng
                            .uniform(cfg.ambient_min_c, cfg.ambient_max_c),
                    },
                );
            }
            4 => {
                if cap_on && rng.f64() < 0.4 {
                    sc = sc
                        .event(t, Action::SetPowerCap { watts: None });
                    cap_on = false;
                } else {
                    sc = sc.event(
                        t,
                        Action::SetPowerCap {
                            watts: Some(
                                rng.uniform(cfg.cap_min_w, cfg.cap_max_w),
                            ),
                        },
                    );
                    cap_on = true;
                }
            }
            5 => {
                let w: Vec<f64> = (0..n_apps)
                    .map(|_| rng.uniform(0.05, 1.0))
                    .collect();
                sc = sc.event(t, Action::SetAppWeights { weights: w });
            }
            _ => {
                let name = cfg.swap_pool
                    [rng.below(cfg.swap_pool.len() as u64) as usize]
                    .clone();
                sc = sc.event(t, Action::SetScheduler { name });
            }
        }
    }

    // Hotplug recovery for every still-failed PE, in time order.
    pending.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
    });
    for (rt, pe) in pending {
        sc = sc.event(rt.max(t), Action::PeRestore { pe });
        t = rt.max(t);
    }

    sc.validate()?;
    sc.validate_for(platform, n_apps)?;
    Ok(sc)
}

/// Generate the whole campaign: `cfg.cases` scenarios.
pub fn generate_all(
    cfg: &FuzzConfig,
    platform: &Platform,
    n_apps: usize,
) -> Result<Vec<Scenario>> {
    (0..cfg.cases).map(|i| generate(cfg, platform, n_apps, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_validates_and_roundtrips() {
        let cfg = FuzzConfig::default();
        cfg.validate().unwrap();
        let j = cfg.to_json().to_string();
        let back = FuzzConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        // Missing keys keep defaults.
        let sparse =
            FuzzConfig::from_json(&Json::parse("{\"cases\": 7}").unwrap())
                .unwrap();
        assert_eq!(sparse.cases, 7);
        assert_eq!(sparse.jobs, cfg.jobs);
    }

    #[test]
    fn config_rejects_bad_ranges() {
        let mut c = FuzzConfig::default();
        c.cases = 0;
        assert!(c.validate().is_err());
        let mut c = FuzzConfig::default();
        c.rate_min_per_ms = 5.0;
        c.rate_max_per_ms = 1.0;
        assert!(c.validate().is_err());
        let mut c = FuzzConfig::default();
        c.ambient_max_c = 400.0;
        assert!(c.validate().is_err());
        let mut c = FuzzConfig::default();
        c.max_failed_frac = 0.99;
        assert!(c.validate().is_err());
    }

    #[test]
    fn generation_is_deterministic_per_case() {
        let cfg = FuzzConfig::default();
        let p = Platform::table2_soc();
        let a = generate(&cfg, &p, 2, 3).unwrap();
        let b = generate(&cfg, &p, 2, 3).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        let c = generate(&cfg, &p, 2, 4).unwrap();
        assert_ne!(a.to_json().to_string(), c.to_json().to_string());
    }

    #[test]
    fn fault_storms_never_orphan_a_class_and_always_recover() {
        let mut cfg = FuzzConfig::default();
        cfg.min_events = 10;
        cfg.max_events = 20;
        cfg.max_failed_frac = 0.9; // clamped to n_pes - 1 internally
        let p = Platform::table2_soc();
        for case in 0..40 {
            let sc = generate(&cfg, &p, 2, case).unwrap();
            let mut down: Vec<usize> = Vec::new();
            for ev in &sc.events {
                match ev.action {
                    Action::PeFail { pe } => {
                        down.push(pe);
                        for class in 0..p.classes.len() {
                            let alive = p
                                .pes
                                .iter()
                                .enumerate()
                                .filter(|(i, pe)| {
                                    pe.class == class
                                        && !down.contains(i)
                                })
                                .count();
                            let total = p
                                .pes
                                .iter()
                                .filter(|pe| pe.class == class)
                                .count();
                            assert!(
                                total == 0 || alive >= 1,
                                "case {case}: class {class} fully failed"
                            );
                        }
                    }
                    Action::PeRestore { pe } => {
                        down.retain(|&x| x != pe);
                    }
                    _ => {}
                }
            }
            assert!(
                down.is_empty(),
                "case {case}: PEs {down:?} never restored"
            );
        }
    }
}
