//! Guided design-space exploration end-to-end: search the hardware
//! configuration space of the Table-2 SoC under a mixed
//! wireless + radar workload (WiFi-TX + pulse Doppler) with the
//! `ds3r::dse` engine — the paper's headline use case ("enables both
//! design space exploration and dynamic resource management") driven by
//! an NSGA-II-style multi-objective search instead of an exhaustive
//! sweep.
//!
//! The genome mutates per-cluster PE counts, enabled OPP subsets, the
//! NoC speed grade, and the DTPM power budget; the search minimizes
//! average job latency and energy per job simultaneously and maintains
//! a Pareto-front archive, checkpointed to `dse_checkpoint.json` after
//! every generation (extend the search with `ds3r dse resume
//! --checkpoint dse_checkpoint.json --generations N`; the checkpoint
//! pins the workload).
//!
//! ```sh
//! cargo run --release --example design_space
//! ```
//!
//! Environment knobs (the CI smoke job shrinks the budget with these):
//! * `DSE_POPULATION`  — designs per generation (default 12)
//! * `DSE_GENERATIONS` — evolutionary generations (default 7)
//! * `DSE_JOBS`        — jobs per evaluation (default 200)
//! * `DSE_THREADS`     — evaluation threads (default: all cores)

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::dse::{DseConfig, DseEngine, Objective};
use ds3r::platform::Platform;
use ds3r::util::json::Json;
use ds3r::util::plot;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let apps = vec![
        suite::wifi_tx(WifiParams { symbols: 8 }),
        suite::pulse_doppler(RadarParams { pulses: 8 }),
    ];

    let mut cfg = DseConfig::default();
    cfg.objectives = vec![Objective::Latency, Objective::Energy];
    cfg.population = env_usize("DSE_POPULATION", 12);
    cfg.generations = env_usize("DSE_GENERATIONS", 7);
    cfg.threads = env_usize("DSE_THREADS", 0);
    cfg.sim.scheduler = "etf".into();
    cfg.sim.injection_rate_per_ms = 4.0;
    cfg.sim.max_jobs = env_usize("DSE_JOBS", 200);
    cfg.sim.warmup_jobs = cfg.sim.max_jobs / 10;
    cfg.sim.max_sim_us = 4_000_000.0;

    println!(
        "Guided DSE on the Table-2 SoC — WiFi-TX + pulse-Doppler mix at \
         {} jobs/ms",
        cfg.sim.injection_rate_per_ms
    );
    println!(
        "objectives: latency x energy | budget: {} evaluations \
         ({} generations x {} designs)\n",
        cfg.budget_evals(),
        cfg.generations + 1,
        cfg.population
    );

    let mut engine = DseEngine::new(Platform::table2_soc(), cfg)
        .expect("valid DSE config");
    // Pin the workload in the checkpoint so `ds3r dse resume` rebuilds
    // (and refuses to silently change) the same app mix.
    let mut meta = Json::obj();
    meta.set(
        "apps",
        Json::Arr(vec![
            Json::Str("wifi-tx".into()),
            Json::Str("pulse-doppler".into()),
        ]),
    )
    .set("symbols", Json::Num(8.0))
    .set("pulses", Json::Num(8.0));
    engine.set_workload_meta(meta);
    let checkpoint = std::path::Path::new("dse_checkpoint.json");
    engine
        .run(&apps, Some(checkpoint), |s| {
            println!(
                "gen {:>2}: evals {:>3} (cache hits {:>2}, sims {:>3})  \
                 front {:>3}  hv {:.4}  best latency {:>8.1} us  \
                 energy {:>6.2} mJ/job",
                s.generation,
                s.evals,
                s.cache_hits,
                s.sims,
                s.front_size,
                s.hypervolume,
                s.best[0],
                s.best[1],
            );
        })
        .expect("search completes");

    // The front, most latency-optimal design first.
    let mut rows = Vec::new();
    let mut front = plot::Series::new("pareto front");
    for p in engine.archive().sorted_by_first_objective() {
        rows.push(vec![
            p.genome.id(),
            format!("{:.1}", p.objectives[0]),
            format!("{:.2}", p.objectives[1]),
            p.genome
                .pe_counts
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join("/"),
            p.genome
                .opp_masks
                .iter()
                .map(|m| m.count_ones().to_string())
                .collect::<Vec<_>>()
                .join("/"),
            format!("{:.3}", p.genome.hop_latency_us),
            p.genome
                .power_budget_w
                .map(|w| format!("{w:.1}"))
                .unwrap_or_else(|| "-".into()),
        ]);
        front.push(p.objectives[0], p.objectives[1]);
    }
    println!(
        "\n{}",
        plot::ascii_table(
            &[
                "design",
                "latency us",
                "mJ/job",
                "PEs A15/A7/SCR/FFT",
                "opps",
                "hop us",
                "cap W",
            ],
            &rows
        )
    );
    println!(
        "{}",
        plot::ascii_chart(
            "Pareto front: energy per job vs latency",
            "latency us",
            "mJ/job",
            &[front],
            60,
            14
        )
    );
    println!(
        "{} non-dominated designs — the latency end buys FFT engines and \
         full OPP ladders; the energy end prunes accelerators, caps \
         power, and tolerates queueing.  Checkpoint: {}",
        engine.archive().len(),
        checkpoint.display()
    );
}
