//! The deployable policy model: a multiclass linear softmax over
//! candidate PEs, trained by seeded SGD.
//!
//! A decision scores every candidate PE with a linear function of its
//! feature vector, `score = w[pe_class] · x`, and the policy picks the
//! argmax (training normalizes the scores with a softmax and minimizes
//! cross-entropy against the oracle's choice).  Weights are **per PE
//! class** (A15 / A7 / accelerator...), not per PE instance, so a model
//! generalizes across instance counts — including platforms the DSE
//! engine resizes.
//!
//! Everything is plain `f64` arithmetic in deterministic order with a
//! seeded [`Rng`] shuffle, so `train` is **bit-reproducible**: the same
//! dataset and seed produce the same weight bytes on any thread count
//! (asserted by `rust/tests/integration_learn.rs`).

use crate::rng::Rng;
use crate::util::json::Json;
use crate::{Error, Result};

use super::dataset::Dataset;
use super::features::{FEATURE_NAMES, N_FEATURES};

/// Default oracle-fallback guard: a pick whose projected finish exceeds
/// `guard_ratio ×` the best achievable finish is overridden (see
/// [`super::policy::choose_guarded`]).
pub const DEFAULT_GUARD_RATIO: f64 = 1.25;

/// SGD hyperparameters (seeded, deterministic).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainParams {
    pub epochs: usize,
    pub learning_rate: f64,
    /// L2 weight decay per touched row per sample.
    pub l2: f64,
    /// Seed of the epoch-shuffle stream.
    pub seed: u64,
}

impl Default for TrainParams {
    fn default() -> Self {
        TrainParams { epochs: 10, learning_rate: 0.05, l2: 1e-4, seed: 7 }
    }
}

/// A trained (or hand-written) linear softmax policy.
#[derive(Debug, Clone, PartialEq)]
pub struct SoftmaxModel {
    /// Weight rows (one per PE class), `n_classes × N_FEATURES`
    /// row-major.  PE classes beyond `n_classes` clamp to the last row.
    pub n_classes: usize,
    pub weights: Vec<f64>,
    /// Oracle-fallback guard ratio (≥ 1); see [`DEFAULT_GUARD_RATIO`].
    pub guard_ratio: f64,
    /// Name of the oracle scheduler the model imitates (diagnostics).
    pub oracle: String,
}

impl SoftmaxModel {
    /// All-zero model (uniform scores — only useful as a train target).
    pub fn zeros(n_classes: usize, oracle: &str) -> SoftmaxModel {
        let n_classes = n_classes.max(1);
        SoftmaxModel {
            n_classes,
            weights: vec![0.0; n_classes * N_FEATURES],
            guard_ratio: DEFAULT_GUARD_RATIO,
            oracle: oracle.to_string(),
        }
    }

    /// Linear score of one candidate: `w[class] · x`.  Classes beyond
    /// the trained range clamp to the last row (keeps a model usable on
    /// platforms with more classes than it was trained on).
    #[inline]
    pub fn score(&self, class: usize, x: &[f64]) -> f64 {
        let row = class.min(self.n_classes - 1);
        let w = &self.weights[row * N_FEATURES..(row + 1) * N_FEATURES];
        w.iter().zip(x).map(|(a, b)| a * b).sum()
    }

    /// Argmax over candidate scores (`feats` is `classes.len() ×
    /// N_FEATURES` row-major).  Ties resolve to the lowest candidate
    /// index — deterministic.  Panics on an empty candidate list.
    pub fn predict(&self, classes: &[u16], feats: &[f64]) -> usize {
        assert!(!classes.is_empty(), "predict on empty candidate list");
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (k, &c) in classes.iter().enumerate() {
            let s = self.score(
                c as usize,
                &feats[k * N_FEATURES..(k + 1) * N_FEATURES],
            );
            if s > best.0 {
                best = (s, k);
            }
        }
        best.1
    }

    /// Train a model on `dataset` by SGD over the per-sample softmax
    /// cross-entropy.  Deterministic: samples are visited in a seeded
    /// shuffle order, and all arithmetic is sequential `f64`.
    pub fn train(
        dataset: &Dataset,
        n_classes: usize,
        oracle: &str,
        p: &TrainParams,
        guard_ratio: f64,
    ) -> SoftmaxModel {
        let mut m = SoftmaxModel::zeros(n_classes, oracle);
        m.guard_ratio = guard_ratio;
        let mut order: Vec<usize> = (0..dataset.samples.len()).collect();
        let mut rng = Rng::new(p.seed ^ 0x11AA_11AA_11AA_11AA);
        let mut probs: Vec<f64> = Vec::new();
        let decay = 1.0 - p.learning_rate * p.l2;
        for _ in 0..p.epochs {
            rng.shuffle(&mut order);
            for &si in &order {
                let s = &dataset.samples[si];
                let k = s.classes.len();
                if k == 0 {
                    continue;
                }
                // Softmax over candidate scores (max-shifted).
                probs.clear();
                let mut zmax = f64::NEG_INFINITY;
                for i in 0..k {
                    let z = m.score(
                        s.classes[i] as usize,
                        &s.feats[i * N_FEATURES..(i + 1) * N_FEATURES],
                    );
                    probs.push(z);
                    if z > zmax {
                        zmax = z;
                    }
                }
                let mut sum = 0.0;
                for z in probs.iter_mut() {
                    *z = (*z - zmax).exp();
                    sum += *z;
                }
                for z in probs.iter_mut() {
                    *z /= sum;
                }
                // Cross-entropy gradient: (p_i - y_i) x_i per candidate.
                for i in 0..k {
                    let y = if i == s.chosen as usize { 1.0 } else { 0.0 };
                    let g = probs[i] - y;
                    let row = (s.classes[i] as usize).min(m.n_classes - 1);
                    let x =
                        &s.feats[i * N_FEATURES..(i + 1) * N_FEATURES];
                    let w = &mut m.weights
                        [row * N_FEATURES..(row + 1) * N_FEATURES];
                    for (wj, xj) in w.iter_mut().zip(x) {
                        *wj = *wj * decay - p.learning_rate * g * xj;
                    }
                }
            }
        }
        m
    }

    // ---- JSON artifact ---------------------------------------------------

    /// Serialize as a policy artifact (`kind: "ds3r-il-policy"`).  The
    /// feature schema names ride along so saved models self-describe.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", Json::Str("ds3r-il-policy".into()))
            .set("n_features", Json::Num(N_FEATURES as f64))
            .set("n_classes", Json::Num(self.n_classes as f64))
            .set(
                "feature_names",
                Json::Arr(
                    FEATURE_NAMES
                        .iter()
                        .map(|n| Json::Str(n.to_string()))
                        .collect(),
                ),
            )
            .set("oracle", Json::Str(self.oracle.clone()))
            .set("guard_ratio", Json::Num(self.guard_ratio))
            .set(
                "weights",
                Json::Arr(
                    (0..self.n_classes)
                        .map(|r| {
                            Json::Arr(
                                self.weights[r * N_FEATURES
                                    ..(r + 1) * N_FEATURES]
                                    .iter()
                                    .map(|&w| Json::Num(w))
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            );
        j
    }

    /// Parse and validate a policy artifact.  Rejects a feature-count
    /// mismatch (an artifact from a different schema version), ragged or
    /// non-finite weight rows, and bad guard ratios.
    pub fn from_json(j: &Json) -> Result<SoftmaxModel> {
        if let Some(kind) = j.get("kind").and_then(Json::as_str) {
            if kind != "ds3r-il-policy" {
                return Err(Error::Config(format!(
                    "not an IL policy artifact (kind '{kind}')"
                )));
            }
        }
        let nf = j
            .get("n_features")
            .and_then(Json::as_usize)
            .unwrap_or(N_FEATURES);
        if nf != N_FEATURES {
            return Err(Error::Config(format!(
                "policy artifact carries {nf} features; this build \
                 extracts {N_FEATURES} (schema drift — retrain)"
            )));
        }
        let rows = j.req_arr("weights")?;
        if rows.is_empty() {
            return Err(Error::Config(
                "policy artifact has no weight rows".into(),
            ));
        }
        let n_classes = j
            .get("n_classes")
            .and_then(Json::as_usize)
            .unwrap_or(rows.len());
        if n_classes != rows.len() {
            return Err(Error::Config(format!(
                "policy artifact n_classes {} != {} weight rows",
                n_classes,
                rows.len()
            )));
        }
        let mut weights = Vec::with_capacity(n_classes * N_FEATURES);
        for (r, row) in rows.iter().enumerate() {
            let xs = row.f64_vec().map_err(|_| {
                Error::Config(format!(
                    "policy weight row {r} is not a number array"
                ))
            })?;
            if xs.len() != N_FEATURES {
                return Err(Error::Config(format!(
                    "policy weight row {r} has {} entries, want \
                     {N_FEATURES}",
                    xs.len()
                )));
            }
            if xs.iter().any(|x| !x.is_finite()) {
                return Err(Error::Config(format!(
                    "policy weight row {r} has non-finite entries"
                )));
            }
            weights.extend(xs);
        }
        let guard_ratio = j
            .get("guard_ratio")
            .and_then(Json::as_f64)
            .unwrap_or(DEFAULT_GUARD_RATIO);
        if !guard_ratio.is_finite() || guard_ratio < 1.0 {
            return Err(Error::Config(format!(
                "policy guard_ratio {guard_ratio} must be finite and >= 1"
            )));
        }
        let oracle = j
            .get("oracle")
            .and_then(Json::as_str)
            .unwrap_or("etf")
            .to_string();
        Ok(SoftmaxModel { n_classes, weights, guard_ratio, oracle })
    }

    pub fn load(path: &std::path::Path) -> Result<SoftmaxModel> {
        SoftmaxModel::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learn::dataset::Sample;

    /// Two-candidate samples where the oracle always picks the one with
    /// the lower feature-1 value.
    fn toy_dataset(n: usize) -> Dataset {
        let mut d = Dataset::default();
        for i in 0..n {
            let hi = 1.0 + (i % 3) as f64;
            let mut feats = vec![0.0; 2 * N_FEATURES];
            feats[0] = 1.0; // bias of candidate 0
            feats[1] = hi; // candidate 0 is slow
            feats[N_FEATURES] = 1.0; // bias of candidate 1
            feats[N_FEATURES + 1] = 0.1; // candidate 1 is fast
            d.samples.push(Sample {
                chosen: 1,
                classes: vec![0, 0],
                feats,
            });
        }
        d
    }

    #[test]
    fn sgd_learns_a_separable_preference() {
        let d = toy_dataset(64);
        let p = TrainParams::default();
        let m = SoftmaxModel::train(&d, 1, "etf", &p, 1.25);
        for s in &d.samples {
            assert_eq!(m.predict(&s.classes, &s.feats), 1);
        }
        // Feature 1 (the discriminating one) got a negative weight.
        assert!(m.weights[1] < 0.0, "w = {:?}", m.weights);
    }

    #[test]
    fn training_is_bit_reproducible() {
        let d = toy_dataset(32);
        let p = TrainParams::default();
        let a = SoftmaxModel::train(&d, 2, "etf", &p, 1.25);
        let b = SoftmaxModel::train(&d, 2, "etf", &p, 1.25);
        assert_eq!(a, b);
        for (x, y) in a.weights.iter().zip(&b.weights) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let mut p2 = p;
        p2.seed = 99;
        let c = SoftmaxModel::train(&d, 2, "etf", &p2, 1.25);
        assert_ne!(a.weights, c.weights, "seed must matter");
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let d = toy_dataset(16);
        let m =
            SoftmaxModel::train(&d, 3, "heft", &TrainParams::default(), 1.1);
        let j = Json::parse(&m.to_json().to_string_pretty()).unwrap();
        let back = SoftmaxModel::from_json(&j).unwrap();
        assert_eq!(m, back);
        for (x, y) in m.weights.iter().zip(&back.weights) {
            assert_eq!(x.to_bits(), y.to_bits(), "weight bytes drifted");
        }
    }

    #[test]
    fn rejects_bad_artifacts() {
        // Wrong kind.
        let j = Json::parse(r#"{"kind": "something-else", "weights": [[0]]}"#)
            .unwrap();
        assert!(SoftmaxModel::from_json(&j).is_err());
        // Feature-count drift.
        let j = Json::parse(
            r#"{"kind": "ds3r-il-policy", "n_features": 3,
                "weights": [[0, 0, 0]]}"#,
        )
        .unwrap();
        assert!(SoftmaxModel::from_json(&j).is_err());
        // Ragged row.
        let j = Json::parse(
            r#"{"kind": "ds3r-il-policy", "weights": [[0, 1]]}"#,
        )
        .unwrap();
        assert!(SoftmaxModel::from_json(&j).is_err());
        // Bad guard.
        let mut good = SoftmaxModel::zeros(1, "etf").to_json();
        good.set("guard_ratio", Json::Num(0.5));
        assert!(SoftmaxModel::from_json(&good).is_err());
        // Empty weights.
        let j = Json::parse(r#"{"kind": "ds3r-il-policy", "weights": []}"#)
            .unwrap();
        assert!(SoftmaxModel::from_json(&j).is_err());
    }

    #[test]
    fn class_clamping_keeps_out_of_range_classes_usable() {
        let mut m = SoftmaxModel::zeros(2, "etf");
        // Row 1 prefers high bias; class 7 clamps onto row 1.
        m.weights[N_FEATURES] = 1.0;
        let mut feats = vec![0.0; 2 * N_FEATURES];
        feats[0] = 0.1;
        feats[N_FEATURES] = 5.0;
        assert_eq!(m.predict(&[7, 7], &feats), 1);
    }
}
