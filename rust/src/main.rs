//! ds3r launcher: parses the subcommand, installs the process
//! telemetry (from `--telemetry`/`--progress`/`--log-format`), and
//! dispatches to `cli`.

use ds3r::cli::{self, Args};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = cli::init_telemetry(&args).and_then(|_| match cmd {
        "run" => cli::cmd_run(&args),
        "sweep" => cli::cmd_sweep(&args),
        "scenario" => cli::cmd_scenario(&args),
        "dse" => cli::cmd_dse(&args),
        "learn" => cli::cmd_learn(&args),
        "fuzz" => cli::cmd_fuzz(&args),
        "reproduce" => cli::cmd_reproduce(&args),
        "validate" => cli::cmd_validate(&args),
        "trace" => cli::cmd_trace(&args),
        "query" => cli::cmd_query(&args),
        "store" => cli::cmd_store(&args),
        "list" => Ok(cli::cmd_list()),
        "help" | "--help" | "-h" => Ok(cli::USAGE.to_string()),
        other => Err(ds3r::Error::Config(format!(
            "unknown command '{other}'\n\n{}",
            cli::USAGE
        ))),
    });
    ds3r::telemetry::global().flush();
    match result {
        Ok(text) => {
            print!("{text}");
            // Degraded success: the campaign completed but quarantined
            // failed points (--fail-policy quarantine).  Exit codes:
            // 0 full success, 1 hard error, 2 partial success.
            if cli::partial_failure() {
                std::process::exit(2);
            }
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
