//! Learned runtime resource management: an imitation-learning scheduler
//! subsystem.
//!
//! The paper positions DS3 as enabling "both design space exploration
//! and dynamic resource management"; the DS3 journal version (Arda et
//! al., arXiv:2003.09016) ships learned runtime policies trained
//! against oracle schedulers, and CEDR (arXiv:2204.08962) shows that a
//! pluggable runtime-policy layer is what keeps a DSSoC framework
//! extensible.  This module adds that layer as a **dependency-free
//! imitation-learning pipeline** producing a deployable scheduler:
//!
//! * [`features`] — a fixed, documented feature vector per
//!   (ready-task, candidate-PE) pair, extracted from the
//!   [`crate::sched::SchedContext`] API (exec estimates, queue depths
//!   and cluster utilization, NoC/data-readiness delay, DVFS/thermal
//!   headroom).
//! * [`dataset`] — demonstration collection: a recording scheduler logs
//!   (features → oracle-chosen PE) decisions while simulations run,
//!   with DAgger-style aggregation across rounds so the dataset covers
//!   the states the deployed policy actually visits.
//! * [`model`] — a seeded, deterministic multiclass linear softmax
//!   trained by SGD (no new crates; bit-reproducible via the in-tree
//!   [`crate::rng::Rng`]), JSON-round-tripping as a policy artifact.
//! * [`policy`] — [`IlSched`], registered as `"il"` in
//!   [`crate::sched::create`], with an earliest-finish oracle-fallback
//!   guard bounding how badly a mistrained model can behave.
//! * [`train`] — the collect → train → eval driver, fanned out over
//!   reusable per-thread simulation workers via
//!   [`crate::coordinator::parallel_map_pooled`] (bit-identical across
//!   thread counts because a reset worker is bit-identical to a fresh
//!   build) and reporting IL-vs-oracle latency/energy/agreement.
//!
//! Drive it from the CLI (`ds3r learn collect|train|eval`), the library
//! API ([`train::train_policy`] / [`train::evaluate`]), or
//! `examples/il_scheduler.rs`.  A committed pretrained preset
//! (`rust/data/il_policy.json`) makes `--sched il` work out of the box,
//! and the scenario engine can hot-swap to the learned policy mid-run
//! (`{"action": "set-scheduler", "scheduler": "il"}`).

pub mod dataset;
pub mod features;
pub mod model;
pub mod policy;
pub mod train;

pub use dataset::{Collected, Collector, Dataset, Sample};
pub use features::{FeatureCtx, FEATURE_NAMES, N_FEATURES};
pub use model::{SoftmaxModel, TrainParams, DEFAULT_GUARD_RATIO};
pub use policy::{choose_guarded, IlSched, PRESET_POLICY};
pub use train::{
    collect_round, evaluate, train_policy, train_policy_with, EvalReport,
    EvalRow, TrainSummary,
};

use crate::config::SimConfig;
use crate::util::json::{u64_from_json, u64_to_json, Json};
use crate::{Error, Result};

/// Full configuration of a learn run: the oracle, the DAgger/SGD
/// budget, the collection/evaluation grid, and the base `SimConfig`
/// every simulation inherits.  JSON round-trips (`ds3r learn ...
/// --learn-config file.json`); missing keys keep their defaults, and
/// [`LearnConfig::from_json`] validates on the way in.
#[derive(Debug, Clone)]
pub struct LearnConfig {
    /// Oracle scheduler demonstrations are collected from (`etf`,
    /// `heft`, ... — any registry name except `il` itself).
    pub oracle: String,
    /// Collection/training rounds: 1 = behavioural cloning, more adds
    /// DAgger rounds (policy acts, oracle labels).
    pub rounds: usize,
    /// SGD epochs per training pass.
    pub epochs: usize,
    pub learning_rate: f64,
    /// L2 weight decay.
    pub l2: f64,
    /// Seed of the SGD shuffle stream (independent of workload seeds).
    pub train_seed: u64,
    /// Oracle-fallback guard ratio baked into the trained artifact
    /// (see [`model::DEFAULT_GUARD_RATIO`]).
    pub guard_ratio: f64,
    /// Workload seeds of the collection/evaluation grid.
    pub seeds: Vec<u64>,
    /// Injection rates (jobs/ms) of the grid.
    pub rates_per_ms: Vec<f64>,
    /// Baselines `learn eval` compares against, besides the oracle.
    pub baselines: Vec<String>,
    /// Per-simulation demonstration cap (bounds memory on long runs).
    pub max_samples_per_run: usize,
    /// Base simulation config for every collection/evaluation run
    /// (`seed`, `injection_rate_per_ms` are overridden per grid point).
    pub sim: SimConfig,
    /// Fan-out threads (0 = all available cores).
    pub threads: usize,
}

impl Default for LearnConfig {
    fn default() -> Self {
        let mut sim = SimConfig::default();
        // Collection favours several medium runs over one long one:
        // enough decisions per (seed, rate) point for stable labels, a
        // sim-time wall so saturated grids terminate.
        sim.max_jobs = 150;
        sim.warmup_jobs = 15;
        sim.max_sim_us = 4_000_000.0;
        LearnConfig {
            oracle: "etf".into(),
            rounds: 2,
            epochs: 10,
            learning_rate: 0.05,
            l2: 1e-4,
            train_seed: 7,
            guard_ratio: DEFAULT_GUARD_RATIO,
            seeds: vec![1, 2],
            rates_per_ms: vec![1.5, 3.0],
            baselines: vec!["random".into(), "rr".into()],
            max_samples_per_run: 20_000,
            sim,
            threads: 0,
        }
    }
}

impl LearnConfig {
    /// Resolved fan-out thread count.
    pub fn eval_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            crate::util::default_threads()
        }
    }

    pub fn validate(&self) -> Result<()> {
        // Scheduler names are checked against the registry here, like
        // the scenario engine does at build time — a typo must fail in
        // milliseconds, not after the whole evaluation grid has run.
        let known = crate::sched::builtin_names();
        if self.oracle == "il" || !known.contains(&self.oracle.as_str()) {
            return Err(Error::Config(format!(
                "learn oracle '{}' must be a non-IL scheduler name \
                 (known: {})",
                self.oracle,
                known.join(", ")
            )));
        }
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be >= 1".into()));
        }
        if self.epochs == 0 {
            return Err(Error::Config("epochs must be >= 1".into()));
        }
        if !self.learning_rate.is_finite() || self.learning_rate <= 0.0 {
            return Err(Error::Config(
                "learning_rate must be finite and > 0".into(),
            ));
        }
        if !self.l2.is_finite() || self.l2 < 0.0 {
            return Err(Error::Config(
                "l2 must be finite and >= 0".into(),
            ));
        }
        if !self.guard_ratio.is_finite() || self.guard_ratio < 1.0 {
            return Err(Error::Config(
                "guard_ratio must be finite and >= 1".into(),
            ));
        }
        if self.seeds.is_empty() {
            return Err(Error::Config(
                "seeds must list at least one workload seed".into(),
            ));
        }
        if self.rates_per_ms.is_empty()
            || self
                .rates_per_ms
                .iter()
                .any(|r| !r.is_finite() || *r <= 0.0)
        {
            return Err(Error::Config(
                "rates_per_ms must list positive rates".into(),
            ));
        }
        if let Some(bad) = self
            .baselines
            .iter()
            .find(|b| *b == "il" || !known.contains(&b.as_str()))
        {
            return Err(Error::Config(format!(
                "learn baseline '{bad}' must be a non-IL scheduler name \
                 (known: {})",
                known.join(", ")
            )));
        }
        if self.max_samples_per_run == 0 {
            return Err(Error::Config(
                "max_samples_per_run must be >= 1".into(),
            ));
        }
        self.sim.validate()
    }

    // ---- JSON ------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("oracle", Json::Str(self.oracle.clone()))
            .set("rounds", Json::Num(self.rounds as f64))
            .set("epochs", Json::Num(self.epochs as f64))
            .set("learning_rate", Json::Num(self.learning_rate))
            .set("l2", Json::Num(self.l2))
            .set("train_seed", u64_to_json(self.train_seed))
            .set("guard_ratio", Json::Num(self.guard_ratio))
            .set(
                "seeds",
                Json::Arr(
                    self.seeds.iter().map(|&s| u64_to_json(s)).collect(),
                ),
            )
            .set(
                "rates_per_ms",
                Json::Arr(
                    self.rates_per_ms
                        .iter()
                        .map(|&r| Json::Num(r))
                        .collect(),
                ),
            )
            .set(
                "baselines",
                Json::Arr(
                    self.baselines
                        .iter()
                        .map(|b| Json::Str(b.clone()))
                        .collect(),
                ),
            )
            .set(
                "max_samples_per_run",
                Json::Num(self.max_samples_per_run as f64),
            )
            .set("sim", self.sim.to_json())
            .set("threads", Json::Num(self.threads as f64));
        j
    }

    /// Parse from JSON; missing keys keep their defaults.  Validates.
    pub fn from_json(j: &Json) -> Result<LearnConfig> {
        let mut c = LearnConfig::default();
        if let Some(s) = j.get("oracle").and_then(Json::as_str) {
            c.oracle = s.to_string();
        }
        if let Some(x) = j.get("rounds").and_then(Json::as_usize) {
            c.rounds = x;
        }
        if let Some(x) = j.get("epochs").and_then(Json::as_usize) {
            c.epochs = x;
        }
        if let Some(x) = j.get("learning_rate").and_then(Json::as_f64) {
            c.learning_rate = x;
        }
        if let Some(x) = j.get("l2").and_then(Json::as_f64) {
            c.l2 = x;
        }
        if let Some(v) = j.get("train_seed") {
            c.train_seed = u64_from_json(v).ok_or_else(|| {
                Error::Config(
                    "train_seed must be a non-negative integer (number \
                     or decimal string)"
                        .into(),
                )
            })?;
        }
        if let Some(x) = j.get("guard_ratio").and_then(Json::as_f64) {
            c.guard_ratio = x;
        }
        if let Some(a) = j.get("seeds").and_then(Json::as_arr) {
            c.seeds = a
                .iter()
                .map(|v| {
                    u64_from_json(v).ok_or_else(|| {
                        Error::Config(format!(
                            "seeds: bad entry {}",
                            v.to_string()
                        ))
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("rates_per_ms") {
            c.rates_per_ms = v.f64_vec()?;
        }
        if let Some(a) = j.get("baselines").and_then(Json::as_arr) {
            c.baselines = a
                .iter()
                .map(|v| {
                    v.as_str().map(String::from).ok_or_else(|| {
                        Error::Config(
                            "baselines entries must be strings".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(x) =
            j.get("max_samples_per_run").and_then(Json::as_usize)
        {
            c.max_samples_per_run = x;
        }
        if let Some(sim) = j.get("sim") {
            c.sim = SimConfig::from_json(sim)?;
        }
        if let Some(x) = j.get("threads").and_then(Json::as_usize) {
            c.threads = x;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &std::path::Path) -> Result<LearnConfig> {
        LearnConfig::from_json(&Json::parse_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        LearnConfig::default().validate().unwrap();
        assert!(LearnConfig::default().eval_threads() >= 1);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = LearnConfig::default();
        c.oracle = "heft".into();
        c.rounds = 3;
        c.epochs = 5;
        c.learning_rate = 0.1;
        c.l2 = 0.001;
        c.train_seed = (1u64 << 53) + 7; // exercises the string path
        c.guard_ratio = 1.5;
        c.seeds = vec![4, u64::MAX];
        c.rates_per_ms = vec![0.5, 6.0];
        c.baselines = vec!["rr".into()];
        c.max_samples_per_run = 99;
        c.sim.scheduler = "met".into();
        c.sim.max_jobs = 77;
        c.sim.warmup_jobs = 7;
        c.threads = 3;
        let j = Json::parse(&c.to_json().to_string_pretty()).unwrap();
        let c2 = LearnConfig::from_json(&j).unwrap();
        assert_eq!(c2.oracle, c.oracle);
        assert_eq!(c2.rounds, c.rounds);
        assert_eq!(c2.epochs, c.epochs);
        assert_eq!(c2.learning_rate, c.learning_rate);
        assert_eq!(c2.l2, c.l2);
        assert_eq!(c2.train_seed, c.train_seed);
        assert_eq!(c2.guard_ratio, c.guard_ratio);
        assert_eq!(c2.seeds, c.seeds);
        assert_eq!(c2.rates_per_ms, c.rates_per_ms);
        assert_eq!(c2.baselines, c.baselines);
        assert_eq!(c2.max_samples_per_run, c.max_samples_per_run);
        assert_eq!(c2.sim.scheduler, "met");
        assert_eq!(c2.sim.max_jobs, 77);
        assert_eq!(c2.threads, 3);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"rounds": 4}"#).unwrap();
        let c = LearnConfig::from_json(&j).unwrap();
        assert_eq!(c.rounds, 4);
        assert_eq!(c.oracle, "etf");
        assert_eq!(c.epochs, LearnConfig::default().epochs);
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = LearnConfig::default();
        c.oracle = "il".into();
        assert!(c.validate().is_err());

        // Registry check: typos fail at validate time, not after the
        // whole evaluation grid has run.
        let mut c = LearnConfig::default();
        c.oracle = "warp-speed".into();
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.baselines = vec!["randm".into()];
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.rounds = 0;
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.learning_rate = 0.0;
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.guard_ratio = 0.5;
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.seeds = vec![];
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.rates_per_ms = vec![1.0, -2.0];
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.baselines = vec!["il".into()];
        assert!(c.validate().is_err());

        let mut c = LearnConfig::default();
        c.max_samples_per_run = 0;
        assert!(c.validate().is_err());
    }
}
