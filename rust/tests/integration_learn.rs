//! Learned-scheduler acceptance tests: the collect → train → eval
//! pipeline is bit-reproducible across thread counts, the trained
//! policy is competitive with its oracle while beating the naive
//! baselines, the committed pretrained preset works out of the box, and
//! a scenario can hot-swap to `"il"` mid-run without violating the
//! golden-trace invariants.

use ds3r::app::suite::{self, RadarParams, WifiParams};
use ds3r::config::SimConfig;
use ds3r::learn::{self, LearnConfig, SoftmaxModel};
use ds3r::platform::Platform;
use ds3r::scenario::{Action, Scenario};
use ds3r::sim::Simulation;

fn mixed_apps() -> Vec<ds3r::app::AppGraph> {
    vec![
        suite::wifi_tx(WifiParams { symbols: 4 }),
        suite::pulse_doppler(RadarParams { pulses: 4 }),
    ]
}

fn small_lc() -> LearnConfig {
    let mut lc = LearnConfig::default();
    lc.oracle = "etf".into();
    lc.seeds = vec![1, 2];
    // Below the Figure-3 saturation knee: decision epochs are mostly
    // small, so the oracle's batch ordering and the per-task policy
    // see comparable states — the regime imitation learning targets.
    lc.rates_per_ms = vec![1.0, 2.5];
    lc.rounds = 2;
    lc.epochs = 8;
    lc.sim.max_jobs = 120;
    lc.sim.warmup_jobs = 10;
    lc
}

#[test]
fn collect_train_eval_is_bit_reproducible_across_threads() {
    // The acceptance contract: for a fixed seed the whole pipeline
    // produces the same artifact bytes and the same eval report on 1
    // thread as on 8 — collection aggregates in grid order, training
    // is seeded SGD, evaluation aggregates in input order.
    let platform = Platform::table2_soc();
    let apps = mixed_apps();
    let mut lc = small_lc();
    lc.seeds = vec![1];
    lc.rates_per_ms = vec![2.0];
    lc.sim.max_jobs = 60;
    lc.sim.warmup_jobs = 6;
    lc.epochs = 4;

    let mut run = |threads: usize| {
        lc.threads = threads;
        let (model, _) =
            learn::train_policy(&platform, &apps, &lc).unwrap();
        let report = learn::evaluate(&platform, &apps, &lc, &model).unwrap();
        (model, report)
    };
    let (m1, r1) = run(1);
    let (m8, r8) = run(8);

    // Same artifact bytes...
    assert_eq!(
        m1.to_json().to_string_pretty(),
        m8.to_json().to_string_pretty(),
        "policy artifact bytes diverged across thread counts"
    );
    for (a, b) in m1.weights.iter().zip(&m8.weights) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // ...and the same eval report.
    assert_eq!(r1.rows.len(), r8.rows.len());
    for (a, b) in r1.rows.iter().zip(&r8.rows) {
        assert_eq!(a.scheduler, b.scheduler);
        assert_eq!(
            a.mean_latency_us.to_bits(),
            b.mean_latency_us.to_bits(),
            "{}: latency diverged",
            a.scheduler
        );
        assert_eq!(
            a.energy_per_job_mj.to_bits(),
            b.energy_per_job_mj.to_bits(),
            "{}: energy diverged",
            a.scheduler
        );
        assert_eq!(a.completed, b.completed);
        assert_eq!((a.decisions, a.fallbacks), (b.decisions, b.fallbacks));
    }
    assert_eq!(r1.agreement.to_bits(), r8.agreement.to_bits());
}

#[test]
fn trained_policy_tracks_oracle_and_beats_naive_baselines() {
    // Acceptance: trained on a wifi-tx + pulse-doppler mix, the IL
    // scheduler achieves mean latency within 10% of its ETF oracle
    // while beating random and round-robin on the same seeds×rates
    // grid.
    let platform = Platform::table2_soc();
    let apps = mixed_apps();
    let mut lc = small_lc();
    // A tight deployment guard: the model decides, the earliest-finish
    // fallback bounds the damage of any residual mispredictions (the
    // fallback count below shows how often it had to).
    lc.guard_ratio = 1.1;
    let (model, summary) =
        learn::train_policy(&platform, &apps, &lc).unwrap();
    assert!(summary.samples > 100, "only {} samples", summary.samples);

    let report = learn::evaluate(&platform, &apps, &lc, &model).unwrap();
    let il = report.row("il").unwrap();
    let etf = report.row("etf").unwrap();
    let random = report.row("random").unwrap();
    let rr = report.row("rr").unwrap();
    for row in [il, etf, random, rr] {
        assert_eq!(
            row.completed, row.injected,
            "{} lost jobs",
            row.scheduler
        );
    }
    assert!(
        il.mean_latency_us <= 1.10 * etf.mean_latency_us,
        "il {:.1} us not within 10% of etf {:.1} us",
        il.mean_latency_us,
        etf.mean_latency_us
    );
    assert!(
        il.mean_latency_us < random.mean_latency_us,
        "il {:.1} us does not beat random {:.1} us",
        il.mean_latency_us,
        random.mean_latency_us
    );
    assert!(
        il.mean_latency_us < rr.mean_latency_us,
        "il {:.1} us does not beat rr {:.1} us",
        il.mean_latency_us,
        rr.mean_latency_us
    );
    assert!(il.decisions > 0, "IL decision counters not wired");
    assert!(
        (0.0..=1.0).contains(&report.agreement),
        "agreement {} out of range",
        report.agreement
    );
}

#[test]
fn pretrained_preset_works_out_of_the_box() {
    // `--sched il` with no policy file: the committed preset
    // (rust/data/il_policy.json, baked in at compile time) must load,
    // schedule, and complete every job.
    let preset = SoftmaxModel::from_json(
        &ds3r::util::json::Json::parse(learn::PRESET_POLICY).unwrap(),
    )
    .unwrap();
    let back = SoftmaxModel::from_json(
        &ds3r::util::json::Json::parse(
            &preset.to_json().to_string_pretty(),
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(preset, back, "preset artifact does not round-trip");

    let platform = Platform::table2_soc();
    let apps = mixed_apps();
    let mut cfg = SimConfig::default();
    cfg.scheduler = "il".into();
    cfg.injection_rate_per_ms = 2.0;
    cfg.max_jobs = 80;
    cfg.warmup_jobs = 8;
    let r = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    assert_eq!(r.completed_jobs, 80);
    assert_eq!(r.scheduler, "il");
    assert!(r.sched_decisions > 0, "decision counter not in report");
    // Deterministic given the seed, like every other scheduler.
    let r2 = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    assert_eq!(r.job_latencies_us, r2.job_latencies_us);
    assert_eq!(r.sched_decisions, r2.sched_decisions);
}

#[test]
fn scenario_hot_swap_to_il_keeps_golden_invariants() {
    // A timeline that switches to the learned policy mid-run: no job
    // may be lost, the phases must exactly partition the run, and the
    // swap must be recorded in the report.
    let platform = Platform::table2_soc();
    let apps = vec![suite::wifi_tx(WifiParams { symbols: 4 })];
    let mut cfg = SimConfig::default();
    cfg.scheduler = "etf".into();
    cfg.injection_rate_per_ms = 2.0;
    cfg.max_jobs = 200;
    cfg.warmup_jobs = 20;
    cfg.scenario = Some(Scenario::new(
        "learned-handover",
        "etf baseline, hot-swap to the learned policy at 30 ms",
    )
    .event(30_000.0, Action::SetScheduler { name: "il".into() }));
    let r = Simulation::build(&platform, &apps, &cfg).unwrap().run();

    // No job lost across the swap.
    assert_eq!(r.completed_jobs, r.injected_jobs);
    assert_eq!(r.completed_jobs, 200);
    // The swap is recorded.
    assert!(r.scheduler.contains("il"), "swap not recorded: {}", r.scheduler);
    assert!(r.sched_decisions > 0, "post-swap IL decisions not counted");
    // Phase partition: contiguous, starting at 0, ending at sim end.
    assert_eq!(r.phases.len(), 2, "{:?}", r.phases);
    assert_eq!(r.phases[0].start_us, 0.0);
    for w in r.phases.windows(2) {
        assert_eq!(
            w[0].end_us, w[1].start_us,
            "phases not contiguous: {:?}",
            r.phases
        );
    }
    assert_eq!(r.phases.last().unwrap().end_us, r.sim_time_us);
    let phase_jobs: usize =
        r.phases.iter().map(|p| p.jobs_completed).sum();
    assert_eq!(phase_jobs, r.completed_jobs, "phase job partition");
    // Both phases saw completions (the swap happened mid-stream).
    assert!(r.phases.iter().all(|p| p.jobs_completed > 0));

    // And the run is deterministic across repeats.
    let r2 = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    assert_eq!(r.job_latencies_us, r2.job_latencies_us);
    assert_eq!(r.events_processed, r2.events_processed);
}

#[test]
fn il_policy_file_flag_loads_a_saved_artifact() {
    // Train a tiny model, save it, and run `--sched il` against the
    // file through SimConfig::il_policy.
    let platform = Platform::table2_soc();
    let apps = mixed_apps();
    let mut lc = small_lc();
    lc.seeds = vec![1];
    lc.rates_per_ms = vec![2.0];
    lc.rounds = 1;
    lc.epochs = 2;
    lc.sim.max_jobs = 40;
    lc.sim.warmup_jobs = 4;
    let (model, _) = learn::train_policy(&platform, &apps, &lc).unwrap();

    let dir = std::env::temp_dir().join("ds3r_learn_artifact_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("policy.json");
    model.save(&path).unwrap();

    let mut cfg = SimConfig::default();
    cfg.scheduler = "il".into();
    cfg.il_policy = Some(path.clone());
    cfg.injection_rate_per_ms = 2.0;
    cfg.max_jobs = 40;
    cfg.warmup_jobs = 4;
    let r = Simulation::build(&platform, &apps, &cfg).unwrap().run();
    assert_eq!(r.completed_jobs, 40);

    // A missing artifact fails at build time with a config error.
    cfg.il_policy = Some(dir.join("nonexistent.json"));
    assert!(Simulation::build(&platform, &apps, &cfg).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
